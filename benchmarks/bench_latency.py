"""Paper §IV.B: classification latency — 2.3 ms per window on their RTX
3080. We measure the single-window path (features + GBDT + calibration)
and the batched path on this CPU."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import calibration, gbdt
from repro.core import features as F


def main():
    trained = common.get_trained()

    @jax.jit
    def classify_one(window):
        feats = F.extract_features(window[None])
        probs = jax.nn.softmax(gbdt.predict_logits(trained.params, feats))
        cal = calibration.calibrate(trained.cal, probs)
        return jnp.argmax(cal), jnp.max(cal)

    w = jnp.asarray(np.random.default_rng(0).gamma(2, 10, 60), jnp.float32)
    us_one = common.timeit(
        lambda: jax.block_until_ready(classify_one(w)), warmup=2, iters=20)

    @jax.jit
    def classify_batch(windows):
        feats = F.extract_features(windows)
        probs = jax.nn.softmax(gbdt.predict_logits(trained.params, feats))
        return jnp.argmax(calibration.calibrate(trained.cal, probs), -1)

    wb = jnp.asarray(np.random.default_rng(1).gamma(2, 10, (4096, 60)),
                     jnp.float32)
    us_batch = common.timeit(
        lambda: jax.block_until_ready(classify_batch(wb)), warmup=1,
        iters=5)

    payload = {"single_window_ms": us_one / 1e3,
               "paper_ms": 2.3,
               "batched_us_per_window": us_batch / 4096,
               "batch_size": 4096}
    # content-address the run so the table names the classifier it timed
    from repro.evals import artifacts
    card = artifacts.save_card(
        "bench_latency",
        {"bench": "classification_latency", "batch_size": 4096,
         "classifier": trained.dataset_id}, payload)
    payload["result_card"] = card["hash"]
    common.emit("classification_latency", us_one,
                f"ms_per_window={us_one/1e3:.2f}_paper=2.3", payload)


if __name__ == "__main__":
    main()
