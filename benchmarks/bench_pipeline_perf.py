"""§Perf (measurable half): wall-clock throughput of the AAPA pipeline on
this host — paper-faithful baseline vs optimized paths.

* feature extraction: per-window jnp pipeline (paper's pandas/numpy
  analogue) vs batched jnp vs the fused Pallas kernel (interpret mode on
  CPU — kernel wins land on TPU; the batched-vs-per-window delta is the
  CPU-measurable part).
* Holt-Winters backtesting: lax.scan reference vs Pallas kernel.
* cluster simulation: workload-days/minute vs the paper's 7 min/day.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import features as F
from repro.core.controllers import hpa_controller
from repro.kernels import ops
from repro.sim.cluster import SimConfig, make_simulator


def main():
    rng = np.random.default_rng(0)
    N = 16384
    w = jnp.asarray(rng.gamma(2.0, 10.0, (N, 60)), jnp.float32)

    # baseline A: one window at a time (paper's per-window loop)
    one = jax.jit(lambda x: F.extract_features(x[None]))
    jax.block_until_ready(one(w[0]))
    t0 = time.time()
    for i in range(256):
        jax.block_until_ready(one(w[i]))
    per_window_us = (time.time() - t0) / 256 * 1e6

    # baseline B: batched jnp
    batched = jax.jit(F.extract_features)
    us_b = common.timeit(lambda: jax.block_until_ready(batched(w)),
                         warmup=1, iters=3)

    # optimized: fused kernel path (interpret on CPU)
    us_k = common.timeit(
        lambda: jax.block_until_ready(ops.extract_features_fused(w)),
        warmup=1, iters=3)

    feat_payload = {
        "per_window_loop_us_per_window": per_window_us,
        "batched_jnp_us_per_window": us_b / N,
        "fused_kernel_interp_us_per_window": us_k / N,
        "speedup_batched_vs_loop": per_window_us / (us_b / N),
        "n_windows": N,
    }

    # Holt-Winters: scan ref vs kernel
    y = jnp.asarray(rng.gamma(2.0, 5.0, (64, 1440)), jnp.float32)
    from repro.kernels import ref as KR
    us_hw_ref = common.timeit(
        lambda: jax.block_until_ready(KR.holt_winters_ref(y)), warmup=1, iters=3)
    us_hw_k = common.timeit(
        lambda: jax.block_until_ready(ops.holt_winters(y)), warmup=1, iters=3)

    # simulator throughput
    cfg = SimConfig()
    sim = make_simulator(hpa_controller(cfg), cfg)
    rates = jnp.asarray(rng.poisson(1000, (32, 1440)), jnp.float32)
    jax.block_until_ready(sim(rates).served)  # compile
    t0 = time.time()
    jax.block_until_ready(sim(rates).served)
    sim_s = time.time() - t0
    days_per_min = 32 / sim_s * 60

    payload = {
        "features": feat_payload,
        "holt_winters": {"scan_ref_us": us_hw_ref,
                         "pallas_interp_us": us_hw_k, "series": 64,
                         "len": 1440},
        "simulator": {"workload_days_per_minute": days_per_min,
                      "s_per_workload_day": sim_s / 32,
                      "paper_s_per_workload_day": 420.0,
                      "speedup_vs_paper": 420.0 / (sim_s / 32)},
    }
    common.emit("pipeline_perf", us_b / N,
                f"sim_days_per_min={days_per_min:.0f}_speedup_vs_paper="
                f"{420.0/(sim_s/32):.0f}x", payload)


if __name__ == "__main__":
    main()
