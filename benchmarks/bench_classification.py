"""Paper §V.A + Table IV: classifier accuracy and confusion matrix on the
held-out test days, plus the weak-label distribution (paper: PERIODIC
70.2%, SPIKE 17.6%, STATIONARY 12.0%, RAMP 0.2%; accuracy 99.8%)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro.core import gbdt
from repro.core.archetypes import ARCHETYPE_NAMES


def main():
    trained = common.get_trained()
    loader = common.get_loader()
    X, y, _ = loader.arrays("test")

    us = common.timeit(
        lambda: np.asarray(gbdt.predict(trained.params,
                                        jnp.asarray(X[:4096]))),
        warmup=1, iters=3)

    pred = np.asarray(gbdt.predict(trained.params, jnp.asarray(X)))
    acc = float((pred == y).mean())
    conf = np.zeros((4, 4), np.int64)
    for t, p in zip(y, pred):
        conf[t, p] += 1

    dist = np.asarray([loader.manifest["card"]["class_balance"][n]
                       for n in ARCHETYPE_NAMES])
    payload = {
        "dataset": loader.dataset_id,
        "test_accuracy": acc,
        "paper_accuracy": 0.998,
        "confusion_matrix": conf.tolist(),
        "confusion_labels": ARCHETYPE_NAMES,
        "label_distribution": {n: float(d) for n, d in
                               zip(ARCHETYPE_NAMES, dist)},
        "paper_label_distribution": {"PERIODIC": 0.702, "SPIKE": 0.176,
                                     "STATIONARY_NOISY": 0.120,
                                     "RAMP": 0.002},
        "n_test_windows": int(len(y)),
        "train_acc": trained.train_acc, "val_acc": trained.val_acc,
    }
    common.emit("classification_tableIV", us,
                f"test_acc={acc:.4f}_paper=0.998", payload)
    print("# confusion matrix (rows=true PERI/SPIKE/STAT/RAMP):")
    for name, row in zip(ARCHETYPE_NAMES, conf):
        print(f"#   {name:17s} {row}")

    # ---- host inference: flattened node tables vs per-round scan -------
    # Measured on the FULL test split: the table path's cache-blocked
    # lockstep traversal wins at paper-scale batches (the pipeline
    # scores whole splits); at toy batch sizes the two are at parity.
    import jax
    Xq = jnp.asarray(X)
    tables = jax.jit(gbdt.predict_logits)
    scan = jax.jit(gbdt.predict_logits_scan)
    tt = common.timeit(
        lambda: jax.block_until_ready(tables(trained.params, Xq)),
        warmup=1, iters=5)
    ts = common.timeit(
        lambda: jax.block_until_ready(scan(trained.params, Xq)),
        warmup=1, iters=5)
    gp = {"rows": int(Xq.shape[0]),
          "rounds": int(trained.params.feat.shape[0]),
          "depth": int(trained.params.depth),
          "tables_us": tt, "scan_us": ts, "tables_speedup": ts / tt}
    common.emit("classification_gbdt_tables", tt,
                f"tables_vs_scan={ts / tt:.2f}x", gp)


if __name__ == "__main__":
    main()
