"""Policy auto-tuning throughput + tuned-vs-default REI deltas: the
numbers behind BENCH_tuning.json.

Three parts:

* ``tuning_throughput`` — fused candidate evaluation (`repro.scaling.
  batch.make_grid_evaluator` driven through `repro.tuning`): a 10^3-point
  hpa grid (traced target x cooldown_min x tolerance, one compile) scored
  in one dispatch, reported as candidates/sec (smoke: a 64-point grid).
* ``tuning_refine`` / ``tuning_population`` — search-to-convergence for
  grid+refine and population hillclimb on `archetype_pure` (SPIKE) and
  the drift scenario `diurnal_ramp`: tuned-vs-paper-default REI delta,
  rounds until the incumbent stops improving, candidates/sec inside the
  search loop. Cards publish under experiments/tuning, so every winner is
  durable as ``registry.make("tuned:<policy>@<hash>")`` — the payload
  records the refs and re-verifies one rebuild against its card.

`python -m benchmarks.run tuning --json .` writes BENCH_tuning.json.
"""
from __future__ import annotations

from benchmarks import common
import repro.tuning as tuning
from repro.scaling import registry
from repro.sim.cluster import SimConfig

SCENARIOS = ("archetype_pure", "diurnal_ramp")
FULL = dict(n_workloads=8, minutes=240, grid_points=10,   # 10^3 candidates
            refine=dict(points=5, rounds=4),
            population=dict(population=32, generations=6))
SMOKE = dict(n_workloads=2, minutes=120, grid_points=4,   # 64 candidates
             refine=dict(points=3, rounds=2),
             population=dict(population=8, generations=2))


def _throughput(knobs: dict) -> dict:
    spec = tuning.spec(
        "bench_throughput", policy="hpa", strategy="grid",
        points=knobs["grid_points"], scenario="archetype_pure",
        n_workloads=knobs["n_workloads"], minutes=knobs["minutes"])
    cands = tuning.grid_candidates(spec.space, spec.points)
    rates = tuning.build_rates(spec)
    evaluate = tuning.make_evaluator(spec)
    evaluate(cands, rates)                       # compile
    us = common.timeit(lambda: evaluate(cands, rates), warmup=0, iters=3)
    return {"candidates": len(cands), "workloads": knobs["n_workloads"],
            "minutes": knobs["minutes"],
            "compiles": evaluate._cache_size(),
            "candidates_per_sec": len(cands) / (us / 1e6),
            "lane_minutes_per_sec": (len(cands) * knobs["n_workloads"]
                                     * knobs["minutes"]) / (us / 1e6)}


def _rounds_to_best(trace: list[dict], best_rei: float) -> int:
    for rec in trace:
        if rec["best_rei"] >= best_rei - 1e-12:
            return rec["round"] + 1
    return len(trace)


def _search(strategy: str, scenario: str, knobs: dict) -> dict:
    spec = tuning.spec(
        f"bench_{strategy}_{scenario}", policy="hpa", strategy=strategy,
        scenario=scenario, n_workloads=knobs["n_workloads"],
        minutes=knobs["minutes"], **knobs[
            "refine" if strategy == "grid_refine" else "population"])
    run = tuning.search(spec, force=True)        # fresh timing numbers
    r = run.result
    return {"ref": f"tuned:hpa@{run.card['hash']}",
            "best": r.best, "best_rei": r.best_rei,
            "default_rei": r.default_rei,
            "rei_delta": r.best_rei - r.default_rei,
            "candidates": r.meta["n_candidates"],
            "rounds_to_best": _rounds_to_best(r.trace, r.best_rei),
            "rounds": len(r.trace),
            "candidates_per_sec": r.meta["candidates_per_sec"],
            "wall_s": r.meta["wall_s"]}


def main(smoke: bool = False):
    knobs = SMOKE if smoke else FULL
    payload = {"throughput": _throughput(knobs), "searches": {}}

    tp = payload["throughput"]
    common.emit("tuning_throughput",
                1e6 / tp["candidates_per_sec"],
                f"g{tp['candidates']}_cps={tp['candidates_per_sec']:,.0f}")

    for strategy, tag in (("grid_refine", "tuning_refine"),
                          ("population", "tuning_population")):
        deltas = []
        for scenario in SCENARIOS:
            res = _search(strategy, scenario, knobs)
            payload["searches"][f"{strategy}/{scenario}"] = res
            deltas.append(f"{scenario}:{res['rei_delta']:+.4f}")
        cps = payload["searches"][f"{strategy}/{SCENARIOS[0]}"][
            "candidates_per_sec"]
        common.emit(tag, 1e6 / max(cps, 1e-9), " ".join(deltas))

    # durable-winner check: the tuned: ref rebuilds straight from the card
    ref = payload["searches"][f"grid_refine/{SCENARIOS[0]}"]["ref"]
    ctrl = registry.make(ref, SimConfig())
    payload["tuned_ref_check"] = {"ref": ref, "controller": ctrl.name}
    payload["best_delta"] = max(
        s["rei_delta"] for s in payload["searches"].values())

    common.emit("tuning_best_delta",
                0.0, f"max_rei_delta={payload['best_delta']:+.4f}",
                payload)


if __name__ == "__main__":
    main()
