"""Paper §III.C.3 ablation: uncertainty-aware scaling vs ablated variants.

Four AAPA variants run through the unified evaluation plane
(``repro.evals.matrix.evaluate_controllers``: one fused policies x
workloads scan with in-scan device-side metrics — no host aggregation
loop), and the ablation lands in a content-addressed result card:

* ``calibrated``    — beta-calibrated classifier confidence x the
  forecaster's *native* (residual-EWMA) interval signal;
* ``cls_only``      — classifier confidence alone (no forecast signal);
* ``overconfident`` — c = 1 always (Algorithm 1 disabled);
* ``conformal``     — classifier confidence x a *split-conformal* band
  fit on the training days (the full distribution-free signal path).

The paper claims uncertainty-awareness prevents mis-scaling; we measure
violations + oscillations on noisy workloads.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.data.azure_synth import generate_traces
from repro.evals import artifacts, matrix
from repro.forecast import conformal, registry as forecast_registry
from repro.scaling import registry
from repro.sim.cluster import SimConfig

SEED = 77
REPLAY_DAY = 12
FIELDS = ("slo_violation_rate", "cold_start_rate", "oscillations",
          "replica_minutes", "scaling_actions")


def main():
    trained = common.get_trained()
    cfg = SimConfig()
    calibrated = trained.make_classify()

    def overconfident(feats):
        arch, conf = calibrated(feats)
        return arch, jnp.float32(1.0)

    traces = generate_traces(n_functions=32, n_days=13, seed=SEED)
    rates = jnp.asarray(
        traces.counts[:, (REPLAY_DAY - 1) * 1440:REPLAY_DAY * 1440])

    # split-conformal band from the training days (held-out from replay)
    fcst = forecast_registry.make("holt_winters")
    band = conformal.calibrate(fcst, traces.counts[:8, :3 * 1440],
                               alpha=0.9)

    variants = {
        "calibrated": registry.get_controller(
            "aapa", cfg, classify=calibrated, forecast_confidence=True),
        "cls_only": registry.get_controller(
            "aapa", cfg, classify=calibrated, forecast_confidence=False),
        "overconfident": registry.get_controller(
            "aapa", cfg, classify=overconfident,
            forecast_confidence=False),
        "conformal": registry.get_controller(
            "aapa", cfg, classify=calibrated, band=band),
    }
    pooled, _ = matrix.evaluate_controllers(list(variants.values()),
                                            rates, cfg)

    res = {name: {f: float(getattr(pooled, f)[i]) for f in FIELDS}
           for i, name in enumerate(variants)}
    res["conformal_band"] = {"q": float(band.q), "alpha": band.alpha,
                             "confidence": float(
                                 conformal.confidence(band))}

    card = artifacts.save_card(
        "bench_uncertainty",
        {"variants": sorted(variants), "seed": SEED, "day": REPLAY_DAY,
         "alpha": 0.9, "classifier": trained.dataset_id},
        res)
    res["result_card"] = card["hash"]

    dv = (res["overconfident"]["slo_violation_rate"]
          - res["calibrated"]["slo_violation_rate"])
    common.emit("uncertainty_ablation", 0.0,
                f"viol_delta_vs_overconfident={dv:+.5f}", res)


if __name__ == "__main__":
    main()
