"""Paper §III.C.3 ablation: uncertainty-aware scaling (beta-calibrated
confidence modulating Table III via Algorithm 1) vs an always-confident
variant (c=1). The paper claims uncertainty-awareness prevents
mis-scaling; we measure violations + oscillations on noisy workloads."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core.controllers import aapa_controller
from repro.data.azure_synth import generate_traces
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig, make_simulator


def main():
    trained = common.get_trained()
    cfg = SimConfig()
    calibrated = trained.make_classify()

    def overconfident(feats):
        arch, conf = calibrated(feats)
        return arch, jnp.float32(1.0)

    traces = generate_traces(n_functions=32, n_days=13, seed=77)
    rates = jnp.asarray(traces.counts[:, 11 * 1440:12 * 1440])

    res = {}
    for name, classify in (("calibrated", calibrated),
                           ("overconfident", overconfident)):
        out = make_simulator(aapa_controller(cfg, classify), cfg)(rates)
        jax.block_until_ready(out.served)
        m = M.aggregate(out, workload_axis=True)
        res[name] = {"slo_violation_rate": m.slo_violation_rate,
                     "cold_start_rate": m.cold_start_rate,
                     "oscillations": m.oscillations,
                     "replica_minutes": m.replica_minutes,
                     "scaling_actions": m.scaling_actions}

    dv = (res["overconfident"]["slo_violation_rate"]
          - res["calibrated"]["slo_violation_rate"])
    common.emit("uncertainty_ablation", 0.0,
                f"viol_delta_vs_overconfident={dv:+.5f}", res)


if __name__ == "__main__":
    main()
