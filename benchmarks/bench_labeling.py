"""Paper §III.B.2: weak-supervision quality — LF coverage, conflict rate,
abstain rate, and throughput of the labeling pass over the full dataset."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import labeling as L
from repro.core import pipeline
from repro.data import windows as W


def main():
    traces = common.get_traces()
    ds = W.make_windows(traces)
    X, y, conf = pipeline.featurize_and_label(ds)

    votes = np.asarray(L.apply_lfs(jnp.asarray(X[:50000])))
    fired = votes >= 0
    coverage = fired.mean(axis=0)            # per-LF firing rate
    # conflict: window where two LFs disagree (both fired, diff class)
    n_conflict = 0
    for row in votes:
        v = row[row >= 0]
        if len(v) > 1 and len(set(v.tolist())) > 1:
            n_conflict += 1
    us = common.timeit(
        lambda: jax.block_until_ready(
            L.weak_label(jnp.asarray(X[:8192]))), warmup=1, iters=3)

    payload = {
        "n_windows": int(len(ds)),
        "abstain_rate": float((y < 0).mean()),
        "mean_vote_confidence": float(conf[y >= 0].mean()),
        "lf_coverage": {fn.__name__: float(c) for fn, c in
                        zip(L.LABELING_FUNCTIONS, coverage)},
        "conflict_rate": n_conflict / len(votes),
        "label_us_per_window": us / 8192,
    }
    common.emit("weak_supervision", us / 8192,
                f"abstain={payload['abstain_rate']:.3f}_conflict="
                f"{payload['conflict_rate']:.3f}", payload)


if __name__ == "__main__":
    main()
