"""Paper §III.B.2: weak-supervision quality + AAPAset builder throughput.

LF coverage/conflict/abstain come straight off the artifact's dataset
card (computed once at build time); the measured half is the chunked
jitted builder — windows/sec through the fused feature+label step, and
content-addressed build vs cache-hit wall time."""
from __future__ import annotations

import time

from benchmarks import common
from repro import aapaset
from repro.aapaset.build import featurize_windows


def main():
    # build-or-load the paper-scale artifact (shared with the other
    # benches via common.get_loader); time whichever path runs
    cfg = aapaset.get(common.BENCH_DATASET)
    cached = aapaset.is_cached(cfg)
    t0 = time.time()
    loader = common.get_loader()
    build_s = time.time() - t0
    built, card = loader.data, loader.manifest["card"]

    # cache-hit load time (always measurable once the artifact exists)
    t0 = time.time()
    aapaset.build_or_load(cfg)
    cache_hit_s = time.time() - t0

    # builder throughput through the fused chunk step (post-compile)
    n = min(len(built), 65536)
    wins = built.windows[:n]
    us = common.timeit(lambda: featurize_windows(wins, chunk=cfg.chunk),
                       warmup=1, iters=3)
    per_window_us = us / n
    windows_per_sec = 1e6 / per_window_us

    payload = {
        "dataset": loader.dataset_id,
        "n_windows": card["n_windows"],
        "abstain_rate": card["abstain_rate"],
        "mean_vote_confidence": card["mean_agreement"],
        "lf_coverage": card["lf_coverage"],
        "conflict_rate": card["lf_conflict_rate"],
        "class_balance": card["class_balance"],
        "builder_windows_per_sec": windows_per_sec,
        "label_us_per_window": per_window_us,
        "build_seconds": None if cached else build_s,
        "cache_hit_seconds": cache_hit_s,
    }
    common.emit("weak_supervision", per_window_us,
                f"windows_per_sec={windows_per_sec:.0f}_cache_hit="
                f"{cache_hit_s:.2f}s", payload)


if __name__ == "__main__":
    main()
