"""Fleet-scale evaluation throughput: the W decade sweep behind
BENCH_fleet.json (the ROADMAP's 10^5-10^6 lane target).

One record per fleet size W in {64, 1e2, 1e3, 1e4, 1e5} (smoke: a
seconds-scale prefix), each a single-dispatch `repro.evals.fleet` run of
the HPA policy over burst_storm workloads: simulated workload-minutes
per wall-second, dispatch count, and peak host RSS. The acceptance bar
the sweep pins (tests/test_bench_fleet.py): W=1e5 completes in ONE
dispatch and its peak RSS stays under 2x the W=1e4 run — the in-scan
pooled accumulators are O(bins), so only the rates tensor grows with W.

A final `fleet_stream` record runs the largest decade through the
donated-accumulator streaming fold (the 1e6-lane mode's mechanics) to
keep its per-chunk overhead measured.

`python -m benchmarks.run fleet --json .` writes BENCH_fleet.json.
"""
from __future__ import annotations

import time

import jax

from benchmarks import common
from repro.evals import fleet

POLICIES = ("hpa",)
MINUTES = 60
W_CHUNK = 1000          # live lanes per chunk at fleet scale
DECADES = (64, 100, 1_000, 10_000, 100_000)
SMOKE_DECADES = (64, 100, 1_000)


def _spec(W: int) -> fleet.FleetSpec:
    return fleet.spec(f"bench_w{W}", policies=POLICIES,
                      scenario="burst_storm", n_workloads=W,
                      w_chunk=min(W, W_CHUNK), minutes=MINUTES, seed=0)


def main(smoke: bool = False):
    decades = SMOKE_DECADES if smoke else DECADES
    payload = {"policies": list(POLICIES), "minutes": MINUTES,
               "w_chunk": W_CHUNK, "n_devices": jax.device_count(),
               "per_w": {}}
    last = None
    for W in decades:          # increasing W so peak RSS is attributable
        res = fleet.run_fleet(_spec(W), warmup=True)
        payload["per_w"][W] = {
            "minutes_per_sec": res.meta["minutes_per_sec"],
            "lane_minutes_per_sec": res.meta["lane_minutes_per_sec"],
            "wall_s": res.meta["wall_s"],
            "dispatches": res.meta["dispatches"],
            "peak_rss_mb": res.meta["peak_rss_mb"],
            "rei_hpa": float(res.rei.rei[0])}
        last = res
    top = max(payload["per_w"])
    if 10_000 in payload["per_w"] and 100_000 in payload["per_w"]:
        payload["rss_ratio_1e5_vs_1e4"] = (
            payload["per_w"][100_000]["peak_rss_mb"]
            / payload["per_w"][10_000]["peak_rss_mb"])

    # streaming fold on the largest decade: the 1e6-lane mode's mechanics
    t0 = time.time()
    res_s = fleet.run_fleet(_spec(top), stream=True)
    payload["stream"] = {
        "workloads": top, "wall_s": res_s.meta["wall_s"],
        "minutes_per_sec": res_s.meta["minutes_per_sec"],
        "dispatches": res_s.meta["dispatches"],
        "peak_rss_mb": res_s.meta["peak_rss_mb"],
        "total_s": time.time() - t0}

    mps = payload["per_w"][top]["minutes_per_sec"]
    common.emit("fleet_decades", 1e6 / mps,
                f"w{top}_mps={mps:,.0f}", payload)
    smps = payload["stream"]["minutes_per_sec"]
    common.emit("fleet_stream", 1e6 / smps, f"w{top}_mps={smps:,.0f}")
    del last


if __name__ == "__main__":
    main()
