"""AAPAset dataset-engine throughput: chunked builder scaling across
chunk sizes, content-addressed cold-build vs cache-hit, and sharded
loader batch throughput — the data path that feeds the classifier the
`aapa`/`hybrid` policies consume."""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks import common
from repro import aapaset
from repro.aapaset.build import featurize_windows
from repro.aapaset.loader import AAPAsetLoader


def main():
    rng = np.random.default_rng(0)
    N = 65536
    wins = rng.gamma(2.0, 10.0, (N, 60)).astype(np.float32)

    # chunk-size sweep through the fused build step (post-compile)
    sweep = {}
    for chunk in (2048, 8192, 32768):
        us = common.timeit(
            lambda c=chunk: featurize_windows(wins, chunk=c),
            warmup=1, iters=3)
        sweep[chunk] = N / (us / 1e6)
    best = max(sweep.values())

    # cold build vs cache hit of the tier-1 artifact in a fresh root
    with tempfile.TemporaryDirectory() as root:
        cfg = aapaset.get("aapaset_ci")
        t0 = time.time()
        aapaset.build_or_load(cfg, root)
        cold_s = time.time() - t0
        t0 = time.time()
        aapaset.build_or_load(cfg, root)
        hit_s = time.time() - t0

        # sharded loader throughput over the built artifact
        loader = AAPAsetLoader.from_name("aapaset_ci", root)
        t0 = time.time()
        n_rows = sum(x.shape[0] for x, _, _ in
                     loader.batches("train", 1024, seed=0))
        loader_rows_per_sec = n_rows / (time.time() - t0)

    payload = {
        "registry": {n: aapaset.config_hash(aapaset.get(n))
                     for n in aapaset.available()},
        "builder_windows_per_sec_by_chunk": {
            str(c): float(v) for c, v in sweep.items()},
        "builder_windows_per_sec_best": best,
        "ci_cold_build_seconds": cold_s,
        "ci_cache_hit_seconds": hit_s,
        "cache_speedup": cold_s / max(hit_s, 1e-9),
        "loader_rows_per_sec": loader_rows_per_sec,
    }
    common.emit("aapaset_engine", 1e6 / best,
                f"windows_per_sec={best:.0f}_cache_speedup="
                f"{cold_s / max(hit_s, 1e-9):.0f}x", payload)


if __name__ == "__main__":
    main()
