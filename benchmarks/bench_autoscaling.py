"""Paper §V.B (Fig 2) + the resource-utilization table: per-archetype SLO
violations, response times, cold starts, and replica-minute ratios for
HPA / Generic-Predictive / AAPA, averaged over 5 seeds with 95% CIs
(paper §IV.E: 5 trials).

The whole figure is ONE ``repro.evals.matrix`` call: archetype-pure
scenarios x seeds x policies with in-scan device-side metrics, plus a
second small matrix sweeping every registered forecaster under the
generic predictive policy. Both runs are content-addressed result cards;
the per-archetype markdown table comes straight from
``evals.artifacts.scenario_table``."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.archetypes import ARCHETYPE_NAMES
from repro.evals import matrix
from repro.forecast import registry as forecast_registry

POLICIES = ("hpa", "predictive", "aapa")
N_PER_SEED = 32      # workloads per trial
N_SEEDS = 5

SPEC = matrix.spec(
    "bench_autoscaling_fig2",
    policies=POLICIES,
    forecasters=("holt_winters",),
    scenarios=tuple(("archetype_pure", {"kind": k})
                    for k in ARCHETYPE_NAMES),
    seeds=tuple(range(1000, 1000 + N_SEEDS)),
    n_workloads=N_PER_SEED, minutes=1440)

SWEEP_SPEC = matrix.spec(
    "bench_forecaster_sweep",
    policies=("predictive",),
    forecasters=tuple(forecast_registry.available()),
    scenarios=(("archetype_mix", {}),),
    seeds=(4242,), n_workloads=8, minutes=1440)


def _ci(vals):
    v = np.asarray(vals, np.float64).reshape(-1)
    if len(v) < 2:
        return float(v.mean()), 0.0
    return float(v.mean()), float(1.96 * v.std(ddof=1) / np.sqrt(len(v)))


def main():
    trained = common.get_trained()
    classify = trained.make_classify()

    t0 = time.time()
    run = matrix.run(SPEC, classify=classify,
                     classifier_id=trained.dataset_id)
    wall = time.time() - t0
    total_days = (len(SPEC.scenarios) * len(SPEC.seeds) * len(POLICIES)
                  * N_PER_SEED)
    perw = run.result.per_workload               # fields [S, Z, 1, P, W]

    # a cache hit only loads the result card — its wall clock says
    # nothing about simulator throughput, so report it as such
    payload = {"wall_s": wall, "workload_days": total_days,
               "paper_sim_s_per_day": 420.0,
               "sim_s_per_day": None if run.cached else wall / total_days,
               "result_card": run.card["hash"], "cached": run.cached}

    table = {}
    for s, gname in enumerate(ARCHETYPE_NAMES):
        table[gname] = {}
        for p, name in enumerate(POLICIES):
            def pick(f, s=s, p=p):
                return np.asarray(getattr(perw, f))[s, :, 0, p, :]
            table[gname][name] = {
                "slo_violation_rate": _ci(pick("slo_violation_rate")),
                "cold_start_rate": _ci(pick("cold_start_rate")),
                "replica_minutes": _ci(pick("replica_minutes")),
                "mean_response_ms": _ci(pick("mean_response_ms")),
                "p95_response_ms": _ci(pick("p95_response_ms")),
                "oscillations": _ci(pick("oscillations")),
                "n": int(pick("slo_violation_rate").size)}
        h = table[gname]["hpa"]["replica_minutes"][0]
        a = table[gname]["aapa"]["replica_minutes"][0]
        table[gname]["resource_ratio_aapa_vs_hpa"] = a / max(h, 1e-9)
    payload["per_archetype"] = table
    payload["per_archetype_table"] = run.card["tables"]["per_scenario"]
    payload["paper_resource_ratios"] = {"SPIKE": 7.7, "PERIODIC": 2.0,
                                        "RAMP": 2.1,
                                        "STATIONARY_NOISY": 2.0}

    # forecaster sweep: predictive over every registered forecaster, one
    # compiled forecasters x policies x workloads matrix
    sweep = matrix.run(SWEEP_SPEC, classify=classify,
                       classifier_id=trained.dataset_id)
    sm = sweep.result.pooled
    payload["forecaster_sweep"] = {
        f: {"slo_violation_rate":
            float(np.asarray(sm.slo_violation_rate)[0, 0, i, 0]),
            "replica_minutes":
            float(np.asarray(sm.replica_minutes)[0, 0, i, 0])}
        for i, f in enumerate(SWEEP_SPEC.forecasters)}
    payload["forecaster_sweep_card"] = sweep.card["hash"]

    # headline derived numbers
    derived = []
    for gname in ("SPIKE", "STATIONARY_NOISY"):
        hv = table[gname]["hpa"]["slo_violation_rate"][0]
        av = table[gname]["aapa"]["slo_violation_rate"][0]
        red = (hv - av) / max(hv, 1e-9) * 100
        derived.append(f"{gname.lower()}_viol_red={red:.0f}%")
    if run.cached:
        derived.append("cached")
    common.emit("autoscaling_fig2",
                0.0 if run.cached else wall / total_days * 1e6,
                "_".join(derived) or "ok", payload)
    for gname, row in table.items():
        ratio = row.get("resource_ratio_aapa_vs_hpa", float("nan"))
        parts = [f"{name}={row[name]['slo_violation_rate'][0]:.4f}"
                 for name in POLICIES]
        print(f"#  {gname:17s} viol: {' '.join(parts)}  "
              f"rep_ratio={ratio:.1f}x")


if __name__ == "__main__":
    main()
