"""Paper §V.B (Fig 2) + the resource-utilization table: per-archetype SLO
violations, response times, cold starts, and replica-minute ratios for
HPA / Generic-Predictive / AAPA, averaged over 5 seeds with 95% CIs
(paper §IV.E: 5 trials).

Policies resolve through ``repro.scaling.registry`` and ALL of them run
in one jitted policies x workloads simulation
(``repro.scaling.batch.make_batch_simulator``) — one compile, one
dispatch per seed, instead of a per-policy ``make_simulator`` loop."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core.archetypes import ARCHETYPE_NAMES
from repro.data.azure_synth import generate_traces
from repro.scaling import batch, registry
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig

POLICIES = ("hpa", "predictive", "aapa")
N_PER_SEED = 32      # workloads per trial
N_SEEDS = 5
TEST_DAY = 12        # replay a held-out day (days 12-14 are test)


def run_all(trained, policies=POLICIES):
    cfg = SimConfig()
    classify = trained.make_classify()
    ctrls = [registry.get_controller(name, cfg, classify=classify)
             for name in policies]
    sim = batch.make_batch_simulator(ctrls, cfg)   # ONE compiled scan
    rows = {k: {g: [] for g in range(4)} for k in policies}
    t0 = time.time()
    total_days = 0
    for seed in range(N_SEEDS):
        traces = generate_traces(n_functions=N_PER_SEED, n_days=13,
                                 seed=1000 + seed)
        day = traces.counts[:, (TEST_DAY - 1) * 1440:TEST_DAY * 1440]
        out = sim(jnp.asarray(day))                # [P, W, M]
        jax.block_until_ready(out.served)
        total_days += N_PER_SEED * len(policies)
        for p, name in enumerate(policies):
            per = M.per_workload(jax.tree.map(lambda a: a[p], out))
            for i, met in enumerate(per):
                rows[name][int(traces.pattern[i])].append(met)
    wall = time.time() - t0
    return rows, wall, total_days


def _ci(vals):
    v = np.asarray(vals, np.float64)
    if len(v) < 2:
        return float(v.mean()), 0.0
    return float(v.mean()), float(1.96 * v.std(ddof=1) / np.sqrt(len(v)))


def main():
    trained = common.get_trained()
    rows, wall, total_days = run_all(trained)

    payload = {"wall_s": wall, "workload_days": total_days,
               "paper_sim_s_per_day": 420.0,
               "sim_s_per_day": wall / total_days}
    table = {}
    for g, gname in enumerate(ARCHETYPE_NAMES):
        table[gname] = {}
        for name in rows:
            ms = rows[name][g]
            if not ms:
                continue
            viol = _ci([m.slo_violation_rate for m in ms])
            cold = _ci([m.cold_start_rate for m in ms])
            rep = _ci([m.replica_minutes for m in ms])
            resp = _ci([m.mean_response_ms for m in ms])
            p95 = _ci([m.p95_response_ms for m in ms])
            osc = _ci([m.oscillations for m in ms])
            table[gname][name] = {
                "slo_violation_rate": viol, "cold_start_rate": cold,
                "replica_minutes": rep, "mean_response_ms": resp,
                "p95_response_ms": p95, "oscillations": osc,
                "n": len(ms)}
        if "hpa" in table[gname] and "aapa" in table[gname]:
            h = table[gname]["hpa"]["replica_minutes"][0]
            a = table[gname]["aapa"]["replica_minutes"][0]
            table[gname]["resource_ratio_aapa_vs_hpa"] = a / max(h, 1e-9)
    payload["per_archetype"] = table
    payload["paper_resource_ratios"] = {"SPIKE": 7.7, "PERIODIC": 2.0,
                                        "RAMP": 2.1,
                                        "STATIONARY_NOISY": 2.0}

    # forecaster sweep: the predictive family over every registered
    # forecaster, one compiled forecasters x policies x workloads scan
    from repro.forecast import registry as forecast_registry
    fore = forecast_registry.available()
    sweep_traces = generate_traces(n_functions=8, n_days=2, seed=4242)
    sweep_rates = jnp.asarray(sweep_traces.counts[:, -1440:])
    fsim = batch.make_forecast_batch_simulator(("predictive",), fore, cfg)
    fout = fsim(sweep_rates)                            # [F, 1, W, M]
    payload["forecaster_sweep"] = {
        f: {"slo_violation_rate": m.slo_violation_rate,
            "replica_minutes": m.replica_minutes}
        for f, m in ((f, M.aggregate(
            jax.tree.map(lambda a: a[i, 0], fout), workload_axis=True))
            for i, f in enumerate(fore))}

    # headline derived numbers
    derived = []
    for gname in ("SPIKE", "STATIONARY_NOISY"):
        if "hpa" in table[gname] and "aapa" in table[gname]:
            hv = table[gname]["hpa"]["slo_violation_rate"][0]
            av = table[gname]["aapa"]["slo_violation_rate"][0]
            red = (hv - av) / max(hv, 1e-9) * 100
            derived.append(f"{gname.lower()}_viol_red={red:.0f}%")
    common.emit("autoscaling_fig2",
                wall / total_days * 1e6, "_".join(derived) or "ok", payload)
    for gname, row in table.items():
        ratio = row.get("resource_ratio_aapa_vs_hpa", float("nan"))
        parts = []
        for name in POLICIES:
            if name in row:
                v = row[name]["slo_violation_rate"][0]
                parts.append(f"{name}={v:.4f}")
        print(f"#  {gname:17s} viol: {' '.join(parts)}  "
              f"rep_ratio={ratio:.1f}x")


if __name__ == "__main__":
    main()
