"""§Roofline table: read the dry-run + probe JSONs and print the per
(arch x shape) three-term roofline with dominant bottleneck."""
from __future__ import annotations

import json
import pathlib

from benchmarks import common

DRYRUN = pathlib.Path("experiments/dryrun/results.json")
ROOFLINE = pathlib.Path("experiments/roofline/results.json")


def main():
    if not ROOFLINE.exists():
        print("# roofline_probe: experiments/roofline/results.json missing"
              " — run `python -m repro.launch.roofline` first")
        common.emit("roofline", 0.0, "missing")
        return
    probes = json.loads(ROOFLINE.read_text())
    dry = json.loads(DRYRUN.read_text()) if DRYRUN.exists() else {}

    n_ok = 0
    worst = (None, 1.1)
    rows = []
    for key, r in sorted(probes.items()):
        if "error" in r:
            rows.append(f"#  {key:45s} ERROR {r['error'][:60]}")
            continue
        n_ok += 1
        frac = r["roofline_fraction"]
        if frac < worst[1]:
            worst = (key, frac)
        mem_ok = ""
        dr = dry.get(f"{r['arch']}|{r['shape']}|single", {})
        if dr.get("ok"):
            tot = (dr["memory"]["argument_bytes"]
                   + dr["memory"]["temp_bytes"]) / 1e9
            mem_ok = f"mem={tot:.1f}GB"
        rows.append(
            f"#  {key:45s} dom={r['dominant']:10s} "
            f"comp={r['compute_s']:.2e} mem={r['memory_s']:.2e} "
            f"coll={r['collective_s']:.2e} frac={frac:.3f} "
            f"useful={r['useful_flop_ratio']:.2f} {mem_ok}")

    common.emit("roofline", 0.0,
                f"cells={n_ok}_worst_frac={worst[1]:.3f}@{worst[0]}",
                {"cells": probes})
    for row in rows:
        print(row)


if __name__ == "__main__":
    main()
