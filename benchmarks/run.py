# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   python -m benchmarks.run [bench] [--smoke] [--json DIR] [--profile DIR]
#
# --json DIR writes each bench's emitted records to DIR/BENCH_<bench>.json
# (stable schema, sorted keys) so perf numbers diff across PRs; --smoke
# asks benches that support it (bench_sim, bench_fleet, bench_tuning) for
# a seconds-scale variant — the CI tier-1 smoke uploads BENCH_sim.json,
# BENCH_fleet.json and BENCH_tuning.json as workflow artifacts. --profile DIR wraps each bench
# in jax.profiler.trace (one trace subdir per bench, viewable in
# TensorBoard/Perfetto) so a fleet-scale regression is attributed to a
# dispatch, not guessed at.
from __future__ import annotations

import inspect
import json
import pathlib
import sys
import time
import traceback

BENCH_SCHEMA_VERSION = 1


def _write_json(out_dir: pathlib.Path, bench: str, records: list,
                elapsed_s: float, failed: bool, smoke: bool) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    doc = {"schema": BENCH_SCHEMA_VERSION, "bench": bench,
           "smoke": smoke, "elapsed_s": round(elapsed_s, 3),
           "failed": failed, "records": records}
    path = out_dir / f"BENCH_{bench}.json"
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=float)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


def main() -> None:
    from benchmarks import (bench_aapaset, bench_autoscaling,
                            bench_classification, bench_fleet,
                            bench_labeling, bench_latency,
                            bench_pipeline_perf, bench_rei,
                            bench_roofline, bench_sim, bench_tuning,
                            bench_uncertainty)
    from benchmarks import common
    benches = [
        ("sim", bench_sim),
        ("fleet", bench_fleet),
        ("tuning", bench_tuning),
        ("aapaset", bench_aapaset),
        ("labeling", bench_labeling),
        ("classification", bench_classification),
        ("latency", bench_latency),
        ("autoscaling", bench_autoscaling),
        ("rei", bench_rei),
        ("uncertainty", bench_uncertainty),
        ("pipeline_perf", bench_pipeline_perf),
        ("roofline", bench_roofline),
    ]
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    json_dir: pathlib.Path | None = None
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv):
            sys.exit("--json needs a directory argument")
        json_dir = pathlib.Path(argv[i + 1])
        del argv[i:i + 2]
    profile_dir: pathlib.Path | None = None
    if "--profile" in argv:
        i = argv.index("--profile")
        if i + 1 >= len(argv):
            sys.exit("--profile needs a directory argument")
        profile_dir = pathlib.Path(argv[i + 1])
        del argv[i:i + 2]
    argv = [a for a in argv if a != "--smoke"]
    only = argv[0] if argv else None

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in benches:
        if only and only != name:
            continue
        kwargs = {}
        if smoke and "smoke" in inspect.signature(mod.main).parameters:
            kwargs["smoke"] = True
        t0 = time.time()
        failed = False
        common.start_capture()
        trace = None
        if profile_dir is not None:
            import contextlib
            import jax
            trace = contextlib.ExitStack()
            trace.enter_context(
                jax.profiler.trace(str(profile_dir / name)))
        try:
            mod.main(**kwargs)
        except Exception:
            failures += 1
            failed = True
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        finally:
            if trace is not None:
                trace.close()
                print(f"# [{name}] profile -> {profile_dir / name}",
                      flush=True)
        records = common.drain_capture()
        if json_dir is not None:
            # a bench without a smoke variant ran its full workload even
            # under --smoke; label its records accordingly
            _write_json(json_dir, name, records, time.time() - t0, failed,
                        bool(kwargs.get("smoke", False)))
        print(f"# [{name}] {time.time()-t0:.0f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
