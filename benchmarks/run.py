# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (bench_aapaset, bench_autoscaling,
                            bench_classification, bench_labeling,
                            bench_latency, bench_pipeline_perf, bench_rei,
                            bench_roofline, bench_uncertainty)
    benches = [
        ("aapaset", bench_aapaset),
        ("labeling", bench_labeling),
        ("classification", bench_classification),
        ("latency", bench_latency),
        ("autoscaling", bench_autoscaling),
        ("rei", bench_rei),
        ("uncertainty", bench_uncertainty),
        ("pipeline_perf", bench_pipeline_perf),
        ("roofline", bench_roofline),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in benches:
        if only and only != name:
            continue
        t0 = time.time()
        try:
            mod.main()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name},0.0,FAILED")
        print(f"# [{name}] {time.time()-t0:.0f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
