"""Paper §III.D + §V.D: REI per autoscaler and the weight-sensitivity
check (+-0.05 on alpha/beta/gamma changes rankings by <2%).

All policies in the registry are evaluated over a scenario suite from
``repro.scaling.scenarios`` with ONE jitted policies x workloads
simulation per scenario (``repro.scaling.batch``) — the REI / SLO
trade-off table comes out of a single API instead of a per-policy
``make_simulator`` loop."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import rei as R
from repro.scaling import batch, registry, scenarios
from repro.sim import metrics as M

SCENARIOS = (
    ("archetype_mix", dict(n_workloads=16, minutes=1440, seed=3)),
    ("burst_storm", dict(n_workloads=8, minutes=720, seed=4)),
    ("diurnal_ramp", dict(n_workloads=8, minutes=1440, seed=5)),
)


def run_suite(policies, classify):
    """-> {policy: {scenario: aggregate metrics}}."""
    per = {p: {} for p in policies}
    for sc_name, kw in SCENARIOS:
        sc = scenarios.get(sc_name, **kw)
        ctrls = [registry.get_controller(p, sc.cfg, classify=classify)
                 for p in policies]
        sim = batch.make_batch_simulator(ctrls, sc.cfg)
        out = sim(jnp.asarray(sc.rates))            # [P, W, M]
        jax.block_until_ready(out.served)
        n_w = sc.rates.shape[0]
        for i, p in enumerate(policies):
            agg = M.aggregate(jax.tree.map(lambda a: a[i], out),
                              workload_axis=True)
            per[p][sc.name] = {
                "slo_violation_rate": agg.slo_violation_rate,
                "replica_minutes": agg.replica_minutes / n_w,
                "oscillations": agg.oscillations / n_w,
            }
    return per


def _rei_inputs(per, policy):
    rows = per[policy].values()
    return (float(np.mean([r["slo_violation_rate"] for r in rows])),
            float(np.mean([r["replica_minutes"] for r in rows])),
            float(np.mean([r["oscillations"] for r in rows])) + 1.0)


def main():
    trained = common.get_trained()
    policies = registry.available()
    per = run_suite(policies, trained.make_classify())

    reis = {}
    for p in policies:
        b = R.rei(*_rei_inputs(per, p))
        reis[p] = {"rei": b.rei, "s_slo": b.s_slo, "s_eff": b.s_eff,
                   "s_stab": b.s_stab}
    base_rank = sorted(reis, key=lambda k: -reis[k]["rei"])

    # sensitivity: perturb weights, count ranking flips
    flips = 0
    trials = 0
    for d in (+0.05, -0.05):
        for which in range(3):
            w = [0.5, 0.3, 0.2]
            w[which] += d
            w[(which + 1) % 3] -= d
            scores = {p: R.rei(*_rei_inputs(per, p),
                               weights=tuple(w)).rei for p in policies}
            rank = sorted(scores, key=lambda k: -scores[k])
            trials += 1
            if rank != base_rank:
                flips += 1

    payload = {"rei": reis, "ranking": base_rank,
               "per_scenario": per,
               "scenarios": [s for s, _ in SCENARIOS],
               "sensitivity_flips": flips, "sensitivity_trials": trials,
               "paper_claim": "rank changes < 2% under +-0.05"}
    common.emit("rei_metric", 0.0,
                f"rank={'>'.join(base_rank)}_flips={flips}/{trials}",
                payload)


if __name__ == "__main__":
    main()
