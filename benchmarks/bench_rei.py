"""Paper §III.D + §V.D: REI per autoscaler and the weight-sensitivity
check (+-0.05 on alpha/beta/gamma changes rankings by <2%)."""
from __future__ import annotations

import json
import pathlib

import numpy as np

from benchmarks import common
from repro.core import rei as R


def main():
    # reuse the per-archetype table produced by bench_autoscaling
    src = common.BENCH_OUT / "autoscaling_fig2.json"
    if not src.exists():
        import benchmarks.bench_autoscaling as BA
        BA.main()
    data = json.loads(src.read_text())["per_archetype"]

    reis, rankings = {}, {}
    for scaler in ("hpa", "predictive", "aapa"):
        viols, reps, acts = [], [], []
        for g, row in data.items():
            if scaler not in row:
                continue
            viols.append(row[scaler]["slo_violation_rate"][0])
            reps.append(row[scaler]["replica_minutes"][0])
            acts.append(row[scaler]["oscillations"][0] + 1)
        b = R.rei(float(np.mean(viols)), float(np.mean(reps)),
                  float(np.mean(acts)))
        reis[scaler] = {"rei": b.rei, "s_slo": b.s_slo, "s_eff": b.s_eff,
                        "s_stab": b.s_stab}

    base_rank = sorted(reis, key=lambda k: -reis[k]["rei"])

    # sensitivity: perturb weights, count ranking flips
    flips = 0
    trials = 0
    for d in (+0.05, -0.05):
        for which in range(3):
            w = [0.5, 0.3, 0.2]
            w[which] += d
            w[(which + 1) % 3] -= d
            scores = {}
            for scaler in reis:
                viols = [data[g][scaler]["slo_violation_rate"][0]
                         for g in data if scaler in data[g]]
                reps = [data[g][scaler]["replica_minutes"][0]
                        for g in data if scaler in data[g]]
                acts = [data[g][scaler]["oscillations"][0] + 1
                        for g in data if scaler in data[g]]
                scores[scaler] = R.rei(float(np.mean(viols)),
                                       float(np.mean(reps)),
                                       float(np.mean(acts)),
                                       weights=tuple(w)).rei
            rank = sorted(scores, key=lambda k: -scores[k])
            trials += 1
            if rank != base_rank:
                flips += 1

    payload = {"rei": reis, "ranking": base_rank,
               "sensitivity_flips": flips, "sensitivity_trials": trials,
               "paper_claim": "rank changes < 2% under +-0.05"}
    common.emit("rei_metric", 0.0,
                f"rank={'>'.join(base_rank)}_flips={flips}/{trials}",
                payload)


if __name__ == "__main__":
    main()
