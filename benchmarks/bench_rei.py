"""Paper §III.D + §V.D: REI per autoscaler and the weight-sensitivity
check (+-0.05 on alpha/beta/gamma changes rankings by <2%).

Every policy in the registry is evaluated through the unified
``repro.evals`` plane: one ``matrix.run`` call covers policies x
scenarios x seeds with in-scan device-side metrics, scores every cell
with scenario-aware REI, and content-addresses the result card — the
emitted table names the exact run by hash."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.evals import artifacts, matrix
from repro.evals import rei as ER
from repro.scaling import registry

SPEC = matrix.spec(
    "bench_rei",
    policies=tuple(registry.available()),
    forecasters=("holt_winters",),
    scenarios=(("archetype_mix", {}), ("burst_storm", {}),
               ("diurnal_ramp", {})),
    seeds=(3, 4), n_workloads=8, minutes=720)


def main():
    trained = common.get_trained()
    run = matrix.run(SPEC, classify=trained.make_classify(),
                     classifier_id=trained.dataset_id)
    m = run.result.pooled                      # fields [S, Z, F=1, P]
    policies = SPEC.policies

    base = np.asarray(run.result.rei.rei).mean(axis=(0, 1))[0]   # [P]
    reis = {p: {"rei": float(base[i]),
                "s_slo": float(np.asarray(run.result.rei.s_slo)
                               .mean(axis=(0, 1))[0, i]),
                "s_eff": float(np.asarray(run.result.rei.s_eff)
                               .mean(axis=(0, 1))[0, i]),
                "s_stab": float(np.asarray(run.result.rei.s_stab)
                                .mean(axis=(0, 1))[0, i])}
            for i, p in enumerate(policies)}
    base_rank = sorted(reis, key=lambda k: -reis[k]["rei"])

    # sensitivity: the 6 +/-0.05 weight perturbations, batched over every
    # cell; a flip is any perturbation that reorders the mean ranking
    sens = ER.sensitivity(m.slo_violation_rate, m.replica_minutes,
                          m.scaling_actions, minutes=SPEC.minutes,
                          n_workloads=SPEC.n_workloads)
    per = np.asarray(sens.rei).mean(axis=(1, 2))[:, 0]           # [6, P]
    flips = sum(
        [policies[i] for i in np.argsort(-per[k])] != base_rank
        for k in range(per.shape[0]))
    trials = per.shape[0]

    per_scenario = {
        p: {sc: {"slo_violation_rate":
                 float(np.asarray(m.slo_violation_rate)[s, :, 0, i].mean()),
                 "replica_minutes":
                 float(np.asarray(m.replica_minutes)[s, :, 0, i].mean()
                       / SPEC.n_workloads),
                 "oscillations":
                 float(np.asarray(m.oscillations)[s, :, 0, i].mean()
                       / SPEC.n_workloads)}
            for s, sc in enumerate(SPEC.scenario_names())}
        for i, p in enumerate(policies)}

    payload = {"rei": reis, "ranking": base_rank,
               "per_scenario": per_scenario,
               "scenarios": SPEC.scenario_names(),
               "sensitivity_flips": int(flips),
               "sensitivity_trials": int(trials),
               "result_card": run.card["hash"], "cached": run.cached,
               "rei_sensitivity_table":
               artifacts.rei_sensitivity_table(run.result, SPEC),
               "paper_claim": "rank changes < 2% under +-0.05"}
    common.emit("rei_metric", 0.0,
                f"rank={'>'.join(base_rank)}_flips={flips}/{trials}"
                f"_card={run.card['hash']}", payload)


if __name__ == "__main__":
    main()
