"""Simulation-plant throughput (the perf trajectory for the ROADMAP's
"fast as the hardware allows" north star).

Four measurements, all in simulated workload-minutes per wall-second:

* `sim_blocked`  — the control-period-blocked scan vs the SEED tick-level
  scan (decide evaluated on all 60 ticks/minute, per-tick pipeline
  shift + reduction) for the AAPA policy and the HPA baseline. The seed
  implementation is reconstructed inline below so the baseline stays
  measurable after the refactor; `simulate_reference` (tick-level
  decides on the optimized plant) isolates the blocking win alone.
* `sim_batch`    — the O(P) per-controller-lane batch vs the seed's
  stacked O(P^2) design (every lane evaluates all P decides) at P = 1..5.
* `sim_workloads`— blocked-scan scaling in the workload axis.
* `sim_kernel`   — the fused Pallas plant kernel vs its jnp oracle on a
  lane tile. On CPU the kernel runs in INTERPRET mode (a correctness
  vehicle, not a speed claim — the TPU number is the real one).
* `sim_fused_decide` — the kernel-path trajectory per policy: the
  whole-episode fused-decide kernel (`decide` inside the Pallas plant
  kernel) vs the block-head-return blocked scan vs the tick-level
  reference. Interpret mode on CPU, same caveat as `sim_kernel`.
* `sim_gbdt_kernel` — the vectorized GBDT node-table kernel lanes/sec
  vs the host table path on a small synthetic fit.

`python -m benchmarks.run sim --json .` writes the records to
BENCH_sim.json (stable schema) so perf regressions diff across PRs.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.scaling import batch, registry
from repro.scaling.api import Obs, apply_decision
from repro.sim.cluster import (SimConfig, initial_state, simulate,
                               simulate_reference)

EPSF = 1e-9


# ------------------------------------------------ seed implementation ----
# The pre-blocking simulator exactly as shipped by PR 4: decide evaluated
# and masked on every one-second tick, pipeline shifted and re-reduced per
# tick, per-tick outputs materialized and jnp.sum'd per minute.
def _seed_tick(cfg, controller, state, arrivals, sec_in_min, minute_idx):
    ready = state.ready + state.pipeline[0]
    pipeline = jnp.concatenate(
        [state.pipeline[1:], jnp.zeros((1,), jnp.float32)])

    throughput = ready * cfg.rps_per_replica
    work = state.queue + arrivals
    served = jnp.minimum(work, throughput)
    queue = work - served
    wait_aged = state.wait_sum + state.queue
    mean_age = wait_aged / jnp.maximum(work, EPSF)
    wait_sum = wait_aged * queue / jnp.maximum(work, EPSF)
    util_now = served / jnp.maximum(throughput, EPSF)
    congest = 1.0 / jnp.maximum(1.0 - util_now, 0.05)
    resp = (cfg.service_sec * congest + mean_age
            + 0.5 * queue / jnp.maximum(throughput, EPSF))
    resp = jnp.minimum(resp, cfg.resp_cap_sec)
    resp = jnp.where(served > 0, resp, 0.0)
    violated = served * (resp > cfg.slo_sec)
    cold = arrivals * (ready < 0.5)

    util_inst = served / jnp.maximum(throughput, EPSF)
    util_ema = state.util_ema + (1.0 / cfg.metric_tau_sec) * (
        util_inst - state.util_ema)

    total = ready + jnp.sum(pipeline)
    do_ctrl = (sec_in_min % cfg.control_interval_sec) == 0
    obs = Obs(ready_total=total, ready=ready, util_ema=util_ema,
              queue=queue, rate_rps=arrivals,
              rate_history=state.rate_history, minute_idx=minute_idx)
    ctrl_state_new, desired, cool_req = controller.decide(
        state.ctrl_state, obs)
    ctrl_state = jax.tree.map(
        lambda new, old: jnp.where(do_ctrl, new, old),
        ctrl_state_new, state.ctrl_state)
    desired = jnp.clip(desired, 0.0, cfg.max_replicas)

    lim, act = apply_decision(state.lim, total, desired, cool_req,
                              do_ctrl, dt=1.0)
    pipeline = pipeline.at[-1].add(act.add)
    n_start = jnp.sum(pipeline)
    from_pipe = jnp.minimum(act.remove, n_start)
    pipeline = pipeline * (1.0 - from_pipe / jnp.maximum(n_start, EPSF))
    ready = jnp.maximum(ready - (act.remove - from_pipe), 0.0)

    new_state = state._replace(ready=ready, pipeline=pipeline, queue=queue,
                               wait_sum=wait_sum, util_ema=util_ema,
                               lim=lim, ctrl_state=ctrl_state)
    out = (served, violated, cold, ready + jnp.sum(pipeline), resp,
           util_inst, act.scale_up.astype(jnp.float32),
           act.scale_down.astype(jnp.float32), act.oscillation, ready)
    return new_state, out


def _seed_minute(cfg, controller, carry, rate_this_min):
    from repro.sim.cluster import MinuteOut
    state, minute_idx = carry
    arrivals = rate_this_min / 60.0

    def body(st, sec):
        return _seed_tick(cfg, controller, st, arrivals, sec, minute_idx)

    state, outs = jax.lax.scan(body, state, jnp.arange(60, dtype=jnp.int32))
    (served, violated, cold, total_reps, resp, util, ups, downs, osc,
     ready) = outs
    m = MinuteOut(
        served=jnp.sum(served), violated=jnp.sum(violated),
        cold_starts=jnp.sum(cold), replica_seconds=jnp.sum(total_reps),
        queue_end=state.queue, resp_sum=jnp.sum(resp * served),
        resp_max=jnp.max(resp), ups=jnp.sum(ups), downs=jnp.sum(downs),
        oscillations=jnp.sum(osc), util_mean=jnp.mean(util),
        ready_mean=jnp.mean(ready))
    hist = jnp.concatenate([state.rate_history[1:], rate_this_min[None]])
    ctrl_state = controller.on_minute(state.ctrl_state, hist,
                                      minute_idx + 1)
    state = state._replace(rate_history=hist, ctrl_state=ctrl_state)
    return (state, minute_idx + 1), m


def seed_simulate(rates_per_min, controller, cfg):
    """The seed tick-level scan, full MinuteOut contract (pipe_sum rides
    along untouched)."""
    from functools import partial
    (state, _), out = jax.lax.scan(
        partial(_seed_minute, cfg, controller),
        (initial_state(controller, cfg), jnp.int32(0)),
        rates_per_min.astype(jnp.float32))
    return out


def seed_stacked_batch(controllers, cfg):
    """The seed O(P^2) batch: one Controller carrying every component's
    state; every lane evaluates ALL P decides and selects by index."""
    ctrls = list(controllers)

    def stacked(policy_idx):
        def init():
            return tuple(c.init() for c in ctrls)

        def on_minute(state, hist, minute_idx):
            return tuple(c.on_minute(s, hist, minute_idx)
                         for c, s in zip(ctrls, state))

        def decide(state, obs):
            outs = [c.decide(s, obs) for c, s in zip(ctrls, state)]
            new_state = tuple(o[0] for o in outs)
            desired = jnp.stack(
                [jnp.asarray(o[1], jnp.float32) for o in outs])[policy_idx]
            cool = jnp.stack(
                [jnp.asarray(o[2], jnp.float32) for o in outs])[policy_idx]
            return new_state, desired, cool

        from repro.scaling.api import Controller
        return Controller("stacked", init, on_minute, decide)

    def sim_one(idx, rates):
        return seed_simulate(rates, stacked(idx), cfg)

    over_w = jax.vmap(sim_one, in_axes=(None, 0))
    over_p = jax.vmap(over_w, in_axes=(0, None))
    idxs = jnp.arange(len(ctrls), dtype=jnp.int32)
    return jax.jit(lambda rates: over_p(idxs, rates.astype(jnp.float32)))


# ------------------------------------------------------------- timing ----
def _interleaved(fns: dict, args, iters: int) -> dict:
    """min-of-N wall seconds per fn, interleaved so machine noise hits
    every candidate equally."""
    for f in fns.values():
        jax.block_until_ready(f(args))
    times = {k: [] for k in fns}
    for _ in range(iters):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(args))
            times[k].append(time.perf_counter() - t0)
    return {k: min(v) for k, v in times.items()}


def main(smoke: bool = False):
    cfg = SimConfig()
    rng = np.random.default_rng(0)
    W, M = (2, 60) if smoke else (8, 240)
    iters = 2 if smoke else 8
    rates = jnp.asarray(rng.poisson(1200, (W, M)).astype(np.float32))

    # ---- blocked vs seed vs reference, per policy -----------------------
    n_blocks = -(-60 // cfg.control_interval_sec)
    payload = {"workloads": W, "minutes": M,
               "control_interval_sec": cfg.control_interval_sec,
               "decide_evals_per_min": {"seed": 60, "blocked": n_blocks},
               "policies": {}}
    aapa_speedup = 0.0
    for name in ("aapa", "hpa"):
        ctrl = registry.get_controller(name, cfg)
        # full MinuteOut outputs on every candidate: benching a single
        # field would let XLA dead-code the other metrics and flatter
        # whichever path folds them more cheaply
        t = _interleaved({
            "blocked": jax.jit(jax.vmap(
                lambda r, c=ctrl: simulate(r, c, cfg))),
            "seed": jax.jit(jax.vmap(
                lambda r, c=ctrl: seed_simulate(r, c, cfg))),
            "reference": jax.jit(jax.vmap(
                lambda r, c=ctrl: simulate_reference(r, c, cfg))),
        }, rates, iters)
        mps = {k: W * M / v for k, v in t.items()}
        payload["policies"][name] = {
            "minutes_per_sec": mps,
            "speedup_vs_seed": mps["blocked"] / mps["seed"],
            "speedup_vs_reference": mps["blocked"] / mps["reference"]}
        if name == "aapa":
            aapa_speedup = mps["blocked"] / mps["seed"]
    aapa_mps = payload["policies"]["aapa"]["minutes_per_sec"]["blocked"]
    common.emit("sim_blocked", 1e6 / aapa_mps,
                f"aapa_blocked_speedup={aapa_speedup:.1f}x", payload)

    # ---- O(P) vs O(P^2) batching ---------------------------------------
    names = registry.available()
    bp = {"workloads": W, "minutes": M, "per_p": {}}
    ratio_p5 = 0.0
    for P in ((len(names),) if smoke else (1, 3, len(names))):
        ctrls = [registry.get_controller(n, cfg) for n in names[:P]]
        t = _interleaved({
            "o_p": batch.make_batch_simulator(ctrls, cfg),
            "o_p2_seed": seed_stacked_batch(ctrls, cfg),
        }, rates, iters)
        lane_minutes = P * W * M
        bp["per_p"][P] = {
            "lane_minutes_per_sec_o_p": lane_minutes / t["o_p"],
            "lane_minutes_per_sec_o_p2_seed": lane_minutes / t["o_p2_seed"],
            "speedup": t["o_p2_seed"] / t["o_p"],
            "decide_evals_per_ctrl_step": {"o_p": P, "o_p2_seed": P * P}}
        ratio_p5 = t["o_p2_seed"] / t["o_p"]
    P = max(bp["per_p"])
    common.emit("sim_batch",
                1e6 / bp["per_p"][P]["lane_minutes_per_sec_o_p"],
                f"p{P}_opn_vs_op2={ratio_p5:.1f}x", bp)

    # ---- workload-axis scaling -----------------------------------------
    ctrl = registry.get_controller("aapa", cfg)
    ws = {"minutes": M, "per_w": {}}
    for Wn in ((4,) if smoke else (4, 16, 64)):
        r = jnp.asarray(rng.poisson(1200, (Wn, M)).astype(np.float32))
        f = jax.jit(jax.vmap(lambda x: simulate(x, ctrl, cfg)))
        t = _interleaved({"blocked": f}, r, iters)["blocked"]
        ws["per_w"][Wn] = {"minutes_per_sec": Wn * M / t}
    top = max(ws["per_w"])
    common.emit("sim_workloads",
                1e6 / ws["per_w"][top]["minutes_per_sec"],
                f"w{top}_mps={ws['per_w'][top]['minutes_per_sec']:,.0f}", ws)

    # ---- fused plant kernel vs oracle (interpret mode on CPU) ----------
    B, S, T = (8, 30, 14) if smoke else (64, 30, 14)
    st = dict(
        ready=rng.gamma(2.0, 2.0, B), queue=rng.gamma(1.0, 25.0, B),
        wait_sum=rng.gamma(1.0, 5.0, B), util_ema=rng.random(B),
        cooldown=rng.uniform(0, 20, B))
    pipeline = rng.gamma(1.0, 0.6, (B, S)).astype(np.float32)
    args = tuple(jnp.asarray(v, jnp.float32) for v in (
        st["ready"], pipeline, st["queue"], st["wait_sum"],
        st["util_ema"], st["cooldown"], pipeline.sum(1), st["ready"] * 30))
    tk = common.timeit(lambda: jax.block_until_ready(
        kops.plant_tick_block(*args, n_ticks=T, interpret=True)),
        warmup=1, iters=iters)
    tr = common.timeit(lambda: jax.block_until_ready(
        kref.plant_block_ref(*args, n_ticks=T)), warmup=1, iters=iters)
    kp = {"lanes": B, "n_ticks": T, "interpret_mode": True,
          "note": "CPU interpret mode validates the kernel; the TPU "
                  "number is the real speed claim",
          "kernel_us": tk, "ref_us": tr, "ref_over_kernel": tr / tk}
    common.emit("sim_kernel", tk, f"interpret_ref_ratio={tr/tk:.2f}", kp)

    # ---- fused-decide episode kernel trajectory, per policy ------------
    # ci=30 keeps the unrolled-tick jaxpr small enough that the interpret
    # kernel compiles in seconds per policy (the TPU path is agnostic).
    dk_cfg = SimConfig(control_interval_sec=30)
    dk_names = ("hpa",) if smoke else tuple(registry.available())
    dk_M = 24 if smoke else 60
    dk_rates = rates[:, :dk_M]
    dk = {"workloads": W, "minutes": dk_M, "interpret_mode": True,
          "control_interval_sec": dk_cfg.control_interval_sec,
          "note": "CPU interpret mode validates the fused-decide episode "
                  "kernel; the TPU number is the real speed claim",
          "policies": {}}
    for name in dk_names:
        ctrl = registry.get_controller(name, dk_cfg)
        t = _interleaved({
            "fused_decide": jax.jit(
                lambda r, c=ctrl: kops.episode_block(r, c, dk_cfg)),
            "block_head": jax.jit(jax.vmap(
                lambda r, c=ctrl: simulate(r, c, dk_cfg,
                                           decide_kernel=False))),
            "reference": jax.jit(jax.vmap(
                lambda r, c=ctrl: simulate_reference(r, c, dk_cfg))),
        }, dk_rates, iters)
        dk["policies"][name] = {
            "minutes_per_sec": {k: W * dk_M / v for k, v in t.items()},
            "fused_over_block_head": t["block_head"] / t["fused_decide"]}
    lead = "aapa" if "aapa" in dk["policies"] else dk_names[0]
    lead_mps = dk["policies"][lead]["minutes_per_sec"]["fused_decide"]
    common.emit(
        "sim_fused_decide", 1e6 / lead_mps,
        f"{lead}_interpret_fused_vs_blocked="
        f"{dk['policies'][lead]['fused_over_block_head']:.3f}x", dk)

    # ---- GBDT node-table kernel lanes/sec ------------------------------
    from repro.core import gbdt
    Ng = 256 if smoke else 4096
    Fg = 38
    Xs = rng.normal(size=(512, Fg)).astype(np.float32)
    ys = rng.integers(0, 4, 512).astype(np.int32)
    params = gbdt.fit(Xs, ys,
                      gbdt.GBDTConfig(n_rounds=8 if smoke else 20))
    Xq = jnp.asarray(rng.normal(size=(Ng, Fg)).astype(np.float32))
    host_tables = jax.jit(gbdt.predict_logits)
    tgk = common.timeit(lambda: jax.block_until_ready(
        kops.gbdt_logits(params, Xq, interpret=True)),
        warmup=1, iters=iters)
    tgr = common.timeit(lambda: jax.block_until_ready(
        host_tables(params, Xq)), warmup=1, iters=iters)
    gk = {"rows": Ng, "features": Fg, "rounds": int(params.feat.shape[0]),
          "depth": int(params.depth), "interpret_mode": True,
          "kernel_us": tgk, "host_table_us": tgr,
          "kernel_lanes_per_sec": Ng / (tgk / 1e6),
          "note": "CPU interpret mode validates the node-table kernel "
                  "(bit-exact vs the host table path); the TPU number "
                  "is the real speed claim"}
    common.emit("sim_gbdt_kernel", tgk,
                f"lanes_per_sec={Ng / (tgk / 1e6):,.0f}", gk)


if __name__ == "__main__":
    main()
