"""Shared benchmark infrastructure: the paper-scale dataset + trained
classifier, cached under experiments/cache so every table reuses them."""
from __future__ import annotations

import json
import pathlib
import pickle
import time


from repro.core import gbdt, pipeline
from repro.data.azure_synth import generate_traces

CACHE = pathlib.Path("experiments/cache")
BENCH_OUT = pathlib.Path("experiments/bench")

# paper §IV.A scale: 300K windows. 200 functions x 14 days ~= 390K windows
N_FUNCTIONS = 200
N_DAYS = 14
SEED = 0


def get_traces():
    return generate_traces(n_functions=N_FUNCTIONS, n_days=N_DAYS,
                           seed=SEED)


def get_trained(verbose: bool = False) -> pipeline.TrainedAAPA:
    CACHE.mkdir(parents=True, exist_ok=True)
    pkl = CACHE / f"aapa_{N_FUNCTIONS}x{N_DAYS}_s{SEED}.pkl"
    if pkl.exists():
        with open(pkl, "rb") as f:
            return pickle.load(f)
    t0 = time.time()
    trained = pipeline.train_aapa(get_traces(),
                                  gbdt.GBDTConfig(n_rounds=60),
                                  verbose=verbose)
    print(f"# trained AAPA in {time.time()-t0:.0f}s "
          f"(test_acc={trained.test_acc:.4f})")
    with open(pkl, "wb") as f:
        pickle.dump(trained, f)
    return trained


def emit(name: str, us_per_call: float, derived: str, payload=None):
    """CSV line per the harness contract + JSON sidecar."""
    print(f"{name},{us_per_call:.1f},{derived}")
    if payload is not None:
        BENCH_OUT.mkdir(parents=True, exist_ok=True)
        with open(BENCH_OUT / f"{name}.json", "w") as f:
            json.dump(payload, f, indent=1, default=float)


def timeit(fn, *, warmup=1, iters=3):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(iters):
        fn()
    return (time.time() - t0) / iters * 1e6  # us
