"""Shared benchmark infrastructure: the paper-scale AAPAset artifact +
trained classifier, content-addressed under experiments/aapaset so every
table names (and reuses) the exact dataset it ran on."""
from __future__ import annotations

import json
import pathlib
import time

from repro import aapaset
from repro.core import gbdt, pipeline

BENCH_OUT = pathlib.Path("experiments/bench")

# paper §IV.A scale: the ~300K-window registry artifact
BENCH_DATASET = "aapaset_300k"

_LOADER: aapaset.AAPAsetLoader | None = None


def get_loader() -> aapaset.AAPAsetLoader:
    """Build-or-load the paper-scale artifact, shared process-wide so a
    bench that needs both the classifier and the arrays loads the shards
    once."""
    global _LOADER
    if _LOADER is None:
        t0 = time.time()
        _LOADER = aapaset.AAPAsetLoader.from_name(BENCH_DATASET)
        print(f"# dataset {_LOADER.dataset_id} ready in "
              f"{time.time()-t0:.0f}s "
              f"({_LOADER.manifest['card']['n_windows']} windows)")
    return _LOADER


def get_trained(verbose: bool = False) -> pipeline.TrainedAAPA:
    t0 = time.time()
    trained = pipeline.train_classifier(BENCH_DATASET,
                                        gbdt.GBDTConfig(n_rounds=60),
                                        verbose=verbose,
                                        loader_factory=get_loader)
    print(f"# classifier on {trained.dataset_id} ready in "
          f"{time.time()-t0:.0f}s (test_acc={trained.test_acc:.4f})")
    return trained


_RECORDS: list[dict] | None = None


def start_capture() -> None:
    """Begin collecting emitted records (benchmarks/run.py --json)."""
    global _RECORDS
    _RECORDS = []


def drain_capture() -> list[dict]:
    """Return records emitted since start_capture and stop collecting."""
    global _RECORDS
    records, _RECORDS = _RECORDS or [], None
    return records


def emit(name: str, us_per_call: float, derived: str, payload=None):
    """CSV line per the harness contract + JSON sidecar."""
    print(f"{name},{us_per_call:.1f},{derived}")
    if _RECORDS is not None:
        _RECORDS.append({"name": name,
                         "us_per_call": round(float(us_per_call), 1),
                         "derived": derived})
    if payload is not None:
        BENCH_OUT.mkdir(parents=True, exist_ok=True)
        with open(BENCH_OUT / f"{name}.json", "w") as f:
            json.dump(payload, f, indent=1, default=float)


def timeit(fn, *, warmup=1, iters=3):
    """us per call: warmup (compile) discarded, then the MEDIAN of
    `iters` individually-clocked calls — one GC pause or noisy
    neighbor skews a mean, the median shrugs it off."""
    for _ in range(warmup):
        fn()
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    samples.sort()
    n = len(samples)
    mid = samples[n // 2] if n % 2 else (samples[n // 2 - 1]
                                         + samples[n // 2]) / 2
    return mid * 1e6  # us
