"""Workload archetypes and the paper's Table III scaling parameters.

Class ids follow the paper's Table IV ordering:
    0 = PERIODIC, 1 = SPIKE, 2 = STATIONARY_NOISY, 3 = RAMP
"""
from __future__ import annotations

import dataclasses
import enum

import jax.numpy as jnp

N_CLASSES = 4


class Archetype(enum.IntEnum):
    PERIODIC = 0
    SPIKE = 1
    STATIONARY_NOISY = 2
    RAMP = 3


ARCHETYPE_NAMES = ["PERIODIC", "SPIKE", "STATIONARY_NOISY", "RAMP"]


@dataclasses.dataclass(frozen=True)
class ScalingParams:
    """One column of the paper's Table III."""

    target_cpu: float        # utilization target in [0, 1]
    cooldown_min: float      # scale-down cooldown, minutes
    min_replicas: int
    strategy: str            # 'warm_pool' | 'predictive' | 'trend' | 'conservative'
    warm_pool: int = 0       # extra always-on pods beyond demand (spike only)


# Paper Table III, indexed by Archetype value.
TABLE_III: dict[Archetype, ScalingParams] = {
    Archetype.PERIODIC: ScalingParams(0.75, 3.0, 1, "predictive"),
    Archetype.SPIKE: ScalingParams(0.30, 20.0, 2, "warm_pool", warm_pool=2),
    Archetype.STATIONARY_NOISY: ScalingParams(0.55, 12.0, 1, "conservative"),
    Archetype.RAMP: ScalingParams(0.60, 7.0, 1, "trend"),
}


def table_iii_arrays():
    """Table III as jnp arrays indexed by class id (for use inside jit)."""
    order = [Archetype.PERIODIC, Archetype.SPIKE,
             Archetype.STATIONARY_NOISY, Archetype.RAMP]
    tgt = jnp.array([TABLE_III[a].target_cpu for a in order], jnp.float32)
    cool = jnp.array([TABLE_III[a].cooldown_min for a in order], jnp.float32)
    minr = jnp.array([TABLE_III[a].min_replicas for a in order], jnp.float32)
    warm = jnp.array([TABLE_III[a].warm_pool for a in order], jnp.float32)
    return {"target_cpu": tgt, "cooldown_min": cool,
            "min_replicas": minr, "warm_pool": warm}
