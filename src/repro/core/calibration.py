"""Beta calibration (Kull, Silva Filho & Flach, AISTATS 2017) in JAX.

Paper §III.C.3: "We calibrate prediction probabilities using beta
calibration to obtain reliable confidence scores c in [0, 1]."

The beta calibration map is q = sigmoid(a·ln p − b·ln(1−p) + c) with
a, b >= 0. We fit one-vs-rest maps per class on a held-out validation set
by maximizing Bernoulli log-likelihood with full-batch Adam, then
renormalize across classes at prediction time. Confidence = max_k q_k.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-6


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BetaCalibration:
    """Per-class beta-calibration parameters. a,b stored as softplus pre-images."""

    a_raw: jax.Array  # [K]
    b_raw: jax.Array  # [K]
    c: jax.Array      # [K]

    def tree_flatten(self):
        return ((self.a_raw, self.b_raw, self.c), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _beta_map(a_raw, b_raw, c, p):
    a = jax.nn.softplus(a_raw)
    b = jax.nn.softplus(b_raw)
    p = jnp.clip(p, EPS, 1.0 - EPS)
    return jax.nn.sigmoid(a * jnp.log(p) - b * jnp.log1p(-p) + c)


def _nll(params, p, y_bin):
    a_raw, b_raw, c = params
    q = _beta_map(a_raw, b_raw, c, p)
    q = jnp.clip(q, EPS, 1.0 - EPS)
    return -jnp.mean(y_bin * jnp.log(q) + (1.0 - y_bin) * jnp.log1p(-q))


@partial(jax.jit, static_argnames=("steps",))
def _fit_class(p, y_bin, steps: int = 400, lr: float = 0.1):
    """Full-batch Adam on (a_raw, b_raw, c) for one class."""
    params = (jnp.array(0.55), jnp.array(0.55), jnp.array(0.0))  # a=b~1, c=0
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8

    def step(carry, i):
        params, m, v = carry
        g = jax.grad(_nll)(params, p, y_bin)
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i + 1.0
        params = jax.tree.map(
            lambda pp, mm, vv: pp - lr * (mm / (1 - b1**t))
            / (jnp.sqrt(vv / (1 - b2**t)) + eps), params, m, v)
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(
        step, (params, m, v), jnp.arange(steps, dtype=jnp.float32))
    return params


def fit(probs: np.ndarray, labels: np.ndarray) -> BetaCalibration:
    """Fit one-vs-rest beta calibration. probs [N, K], labels [N] int."""
    probs = jnp.asarray(probs, jnp.float32)
    labels = np.asarray(labels)
    K = probs.shape[1]
    a_raw, b_raw, c = [], [], []
    for k in range(K):
        y_bin = jnp.asarray((labels == k).astype(np.float32))
        ar, br, ck = _fit_class(probs[:, k], y_bin)
        a_raw.append(ar), b_raw.append(br), c.append(ck)
    return BetaCalibration(jnp.stack(a_raw), jnp.stack(b_raw), jnp.stack(c))


@jax.jit
def calibrate(cal: BetaCalibration, probs: jax.Array) -> jax.Array:
    """probs [..., K] -> calibrated + renormalized probs [..., K]."""
    q = _beta_map(cal.a_raw, cal.b_raw, cal.c, probs)
    return q / (jnp.sum(q, axis=-1, keepdims=True) + EPS)


def confidence(cal: BetaCalibration, probs: jax.Array) -> jax.Array:
    """Calibrated confidence c in [0,1] = max_k calibrated prob."""
    return jnp.max(calibrate(cal, probs), axis=-1)


def expected_calibration_error(probs: np.ndarray, labels: np.ndarray,
                               n_bins: int = 15) -> float:
    """Standard ECE on max-prob confidence."""
    probs = np.asarray(probs)
    labels = np.asarray(labels)
    conf = probs.max(axis=1)
    pred = probs.argmax(axis=1)
    correct = (pred == labels).astype(np.float64)
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    ece = 0.0
    for i in range(n_bins):
        m = (conf > edges[i]) & (conf <= edges[i + 1])
        if m.sum() == 0:
            continue
        ece += m.mean() * abs(correct[m].mean() - conf[m].mean())
    return float(ece)
