"""Back-compat shim: the autoscaling policies moved to
``repro.scaling.policies`` (one control plane shared by the cluster
simulator and the serving engine). Import from ``repro.scaling`` in new
code; this module re-exports the original names unchanged."""
from __future__ import annotations

from repro.scaling.api import Controller, Obs  # noqa: F401
from repro.scaling.policies import (  # noqa: F401
    AAPAState, HPAState, KPAState, PredState, aapa_controller,
    hpa_controller, hybrid_controller, kpa_controller,
    predictive_controller)

__all__ = ["Controller", "Obs", "AAPAState", "HPAState", "KPAState",
           "PredState", "aapa_controller", "hpa_controller",
           "hybrid_controller", "kpa_controller", "predictive_controller"]
