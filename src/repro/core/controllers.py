"""Autoscaling controllers: Kubernetes HPA, Generic Predictive, and AAPA.

All three plug into ``repro.sim.cluster`` via the Controller protocol and
are fully jittable.

* ``hpa_controller`` — paper §IV.C baseline: reactive, 70% CPU target,
  5-minute downscale stabilization window, 5-minute scale-down cooldown,
  +-10% tolerance band (Kubernetes semantics).
* ``predictive_controller`` — paper §IV.C baseline: uniform Holt-Winters,
  15-minute prediction horizon, no workload differentiation.
* ``aapa_controller`` — the paper's system (§III.C): every 10 minutes,
  extract 38 features from the last 60 minutes, classify the archetype,
  beta-calibrate the confidence, adjust Table III parameters via
  Algorithm 1, and apply the archetype strategy.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import features as F
from repro.core import forecasting as fc
from repro.core import uncertainty
from repro.core.archetypes import table_iii_arrays
from repro.sim.cluster import Controller, Obs, SimConfig

EPSF = 1e-9


# ---------------------------------------------------------------- HPA ----
class HPAState(NamedTuple):
    desired_buf: jax.Array  # ring buffer of recent desired counts
    last_total: jax.Array


def hpa_controller(cfg: SimConfig, *, target: float = 0.70,
                   stabilization_min: float = 5.0,
                   cooldown_min: float = 5.0,
                   tolerance: float = 0.10) -> Controller:
    buf_len = max(int(stabilization_min * 60 / cfg.control_interval_sec), 1)

    def init():
        return HPAState(
            desired_buf=jnp.full((buf_len,), cfg.initial_replicas,
                                 jnp.float32),
            last_total=jnp.float32(cfg.initial_replicas))

    def on_minute(state, hist, minute_idx):
        return state

    def decide(state: HPAState, obs: Obs):
        ratio = obs.util_ema / target
        in_band = jnp.abs(ratio - 1.0) <= tolerance
        raw = jnp.ceil(obs.ready_total * ratio)
        raw = jnp.where(in_band, obs.ready_total, raw)
        # serverless scale-to-zero on sustained idle (Knative-style KPA);
        # the activator path below wakes the endpoint on traffic.
        idle = ((obs.util_ema < 0.02) & (obs.queue <= 0.0)
                & (obs.rate_rps <= 1e-6))
        raw = jnp.where(idle, 0.0, jnp.maximum(raw, 1.0))
        wake = (obs.rate_rps > 0.0) | (obs.queue > 0.0)
        raw = jnp.where(wake, jnp.maximum(raw, 1.0), raw)
        buf = jnp.concatenate([state.desired_buf[1:], raw[None]])
        # downscale stabilization: never below the window max
        stabilized = jnp.maximum(raw, jnp.max(buf))
        desired = jnp.where(raw >= obs.ready_total, raw, stabilized)
        return (HPAState(buf, desired), desired,
                jnp.float32(cooldown_min * 60.0))

    return Controller("hpa", init, on_minute, decide)


# --------------------------------------------------- Generic Predictive ----
class PredState(NamedTuple):
    hw: fc.HWState


def predictive_controller(cfg: SimConfig, *, target: float = 0.70,
                          horizon_min: int = 15, period: int = 60,
                          cooldown_min: float = 5.0) -> Controller:
    def init():
        return PredState(hw=fc.hw_init(period))

    def on_minute(state: PredState, hist, minute_idx):
        return PredState(hw=fc.hw_step(state.hw, hist[-1]))

    def decide(state: PredState, obs: Obs):
        pred_per_min = jnp.maximum(
            fc.hw_forecast_max(state.hw, horizon_min), 0.0)
        need_pred = pred_per_min / 60.0 / (cfg.rps_per_replica * target)
        need_now = obs.rate_rps / (cfg.rps_per_replica * target)
        desired = jnp.ceil(jnp.maximum(need_pred, need_now))
        # scale to zero when neither live traffic nor forecast needs pods
        idle = ((desired < 1.0) & (obs.queue <= 0.0)
                & (obs.rate_rps <= 1e-6))
        desired = jnp.where(idle, 0.0, jnp.maximum(desired, 1.0))
        return state, desired, jnp.float32(cooldown_min * 60.0)

    return Controller("predictive", init, on_minute, decide)


# ------------------------------------------------------------------ AAPA ----
class AAPAState(NamedTuple):
    hw: fc.HWState
    arch: jax.Array         # int32 current archetype
    conf: jax.Array         # f32 calibrated confidence
    cpu_adj: jax.Array
    cool_adj_min: jax.Array
    minrep_adj: jax.Array


def aapa_controller(
        cfg: SimConfig,
        classify: Callable[[jax.Array], tuple[jax.Array, jax.Array]],
        *, stride_min: int = 10, horizon_min: int = 15,
        period: int = 60) -> Controller:
    """`classify(features [38]) -> (class id int32, confidence f32)`,
    typically GBDT + beta calibration (see ``repro.core.pipeline``)."""
    tab = table_iii_arrays()

    def init():
        return AAPAState(hw=fc.hw_init(period),
                         arch=jnp.int32(2),          # start conservative
                         conf=jnp.float32(0.5),
                         cpu_adj=jnp.float32(0.5),
                         cool_adj_min=jnp.float32(5.0),
                         minrep_adj=jnp.float32(1.0))

    def on_minute(state: AAPAState, hist, minute_idx):
        hw = fc.hw_step(state.hw, hist[-1])

        def reclassify(_):
            feats = F.extract_features(hist)
            arch, conf = classify(feats)
            adj = uncertainty.adjust(conf, tab["target_cpu"][arch],
                                     tab["cooldown_min"][arch],
                                     tab["min_replicas"][arch])
            return AAPAState(hw, arch, conf, adj.target_cpu,
                             adj.cooldown_min, adj.min_replicas)

        def keep(_):
            return state._replace(hw=hw)

        do = (minute_idx % stride_min) == 0
        return jax.lax.cond(do, reclassify, keep, None)

    def decide(state: AAPAState, obs: Obs):
        cap = cfg.rps_per_replica * jnp.maximum(state.cpu_adj, 0.05)
        # reactive component (archetype-specific utilization target)
        ratio = obs.util_ema / jnp.maximum(state.cpu_adj, 0.05)
        reactive = jnp.ceil(obs.ready_total * ratio)
        reactive = jnp.where(jnp.abs(ratio - 1.0) <= 0.1,
                             obs.ready_total, reactive)

        # strategy components (paper Table III)
        warm = tab["warm_pool"][state.arch]
        need_now = jnp.ceil(obs.rate_rps / cap)
        spike_d = need_now + warm + state.minrep_adj

        hw_pred = jnp.maximum(fc.hw_forecast_max(state.hw, horizon_min),
                              0.0) / 60.0
        periodic_d = jnp.ceil(hw_pred / cap)

        trend_pred = fc.linear_trend_forecast(
            obs.rate_history[-30:], horizon_min) / 60.0
        ramp_d = jnp.ceil(jnp.maximum(trend_pred, obs.rate_rps) / cap)

        mean_rps = jnp.mean(obs.rate_history[-15:]) / 60.0
        stat_d = jnp.ceil(mean_rps / cap)

        strat = jnp.stack([periodic_d, spike_d, stat_d, ramp_d])[state.arch]
        desired = jnp.maximum(jnp.maximum(reactive, strat),
                              jnp.maximum(state.minrep_adj, 1.0))
        return state, desired, state.cool_adj_min * 60.0

    return Controller("aapa", init, on_minute, decide)
