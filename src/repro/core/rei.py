"""Resource Efficiency Index (paper §III.D).

    REI = alpha * S_SLO + beta * S_eff + gamma * S_stab

S_SLO  = 1 - violation_rate
S_eff  = 1 / normalized_pod_minutes
S_stab = 1 / scaling_actions   (both normalized so scores live in (0, 1])

Default weights alpha=0.5, beta=0.3, gamma=0.2.
"""
from __future__ import annotations

import dataclasses

import numpy as np

DEFAULT_WEIGHTS = (0.5, 0.3, 0.2)


@dataclasses.dataclass(frozen=True)
class REIBreakdown:
    s_slo: float
    s_eff: float
    s_stab: float
    rei: float


def rei(violation_rate: float, pod_minutes: float, scaling_actions: float,
        *, baseline_pod_minutes: float = 1440.0,
        baseline_actions: float = 10.0,
        weights: tuple[float, float, float] = DEFAULT_WEIGHTS) -> REIBreakdown:
    """Compute REI.

    pod_minutes is normalized by `baseline_pod_minutes` (default: one pod
    for a whole day); scaling_actions by `baseline_actions`. Both
    efficiency/stability scores are capped at 1 so REI is in [0, 1].
    """
    a, b, g = weights
    s_slo = float(np.clip(1.0 - violation_rate, 0.0, 1.0))
    norm_pm = max(pod_minutes / baseline_pod_minutes, 1e-9)
    s_eff = float(np.clip(1.0 / norm_pm, 0.0, 1.0))
    norm_act = max(scaling_actions / baseline_actions, 1e-9)
    s_stab = float(np.clip(1.0 / norm_act, 0.0, 1.0))
    return REIBreakdown(s_slo, s_eff, s_stab,
                        a * s_slo + b * s_eff + g * s_stab)


def sensitivity(violation_rate, pod_minutes, scaling_actions,
                delta: float = 0.05, **kw) -> list[REIBreakdown]:
    """REI under weight perturbations of +/- delta (paper §V.D)."""
    a, b, g = DEFAULT_WEIGHTS
    out = []
    for da, db, dg in [(+delta, -delta, 0), (-delta, +delta, 0),
                       (0, +delta, -delta), (0, -delta, +delta),
                       (+delta, 0, -delta), (-delta, 0, +delta)]:
        w = (a + da, b + db, g + dg)
        out.append(rei(violation_rate, pod_minutes, scaling_actions,
                       weights=w, **kw))
    return out
