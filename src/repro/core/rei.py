"""Resource Efficiency Index (paper §III.D) — scalar front-end.

    REI = alpha * S_SLO + beta * S_eff + gamma * S_stab

S_SLO  = 1 - violation_rate
S_eff  = 1 / normalized_pod_minutes
S_stab = 1 / normalized_scaling_actions   (scores clipped into [0, 1])

The math lives in ``repro.evals.rei`` (batched jnp over whole metric
arrays); this module keeps the float dataclass API for scalar callers.
Baselines are scenario-aware — they default from the episode length and
workload count (`minutes=`, `n_workloads=`) — and the paper's §V.D
one-pod-day constants are exactly the defaults (minutes=1440,
n_workloads=1 -> 1440 pod-minutes, 10 actions; pinned by test).

Default weights alpha=0.5, beta=0.3, gamma=0.2.
"""
from __future__ import annotations

import dataclasses

DEFAULT_WEIGHTS = (0.5, 0.3, 0.2)


@dataclasses.dataclass(frozen=True)
class REIBreakdown:
    s_slo: float
    s_eff: float
    s_stab: float
    rei: float


def rei(violation_rate: float, pod_minutes: float, scaling_actions: float,
        *, minutes: float = 1440.0, n_workloads: float = 1.0,
        baseline_pod_minutes: float | None = None,
        baseline_actions: float | None = None,
        weights: tuple[float, float, float] = DEFAULT_WEIGHTS) -> REIBreakdown:
    """Compute REI for one cell.

    pod_minutes is normalized by `baseline_pod_minutes` (default: one pod
    per workload for the episode length), scaling_actions by
    `baseline_actions` (default: the paper's 10 per workload-day,
    prorated). Both scores are capped at 1 so REI is in [0, 1].
    """
    from repro.evals import rei as batched   # lazy: evals imports the sim
    b = batched.rei(violation_rate, pod_minutes, scaling_actions,
                    minutes=minutes, n_workloads=n_workloads,
                    baseline_pod_minutes=baseline_pod_minutes,
                    baseline_actions=baseline_actions, weights=weights)
    return REIBreakdown(float(b.s_slo), float(b.s_eff), float(b.s_stab),
                        float(b.rei))


def sensitivity(violation_rate, pod_minutes, scaling_actions,
                delta: float = 0.05, **kw) -> list[REIBreakdown]:
    """REI under weight perturbations of +/- delta (paper §V.D)."""
    from repro.evals import rei as batched
    out = batched.sensitivity(violation_rate, pod_minutes, scaling_actions,
                              delta=delta, **kw)
    return [REIBreakdown(float(out.s_slo[i]), float(out.s_eff[i]),
                         float(out.s_stab[i]), float(out.rei[i]))
            for i in range(len(batched.SENSITIVITY_DELTAS))]
