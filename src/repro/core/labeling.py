"""Weak supervision: 10 programmatic labeling functions + majority vote.

Paper §III.B.2: spike detection (kurtosis > 10, max-to-median ratio > 20),
periodicity (spectral entropy < 0.5, autocorrelation > 0.6), ramp patterns
(strong linear trends), stationary-noisy patterns. LF outputs are
aggregated with majority voting; the agreement level is a natural
confidence score.

Each LF maps a feature row -> class id in {0..3} or ABSTAIN (-1).
All LFs are pure jnp and vectorize over leading axes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.archetypes import Archetype, N_CLASSES
from repro.core.features import FEATURE_NAMES

ABSTAIN = -1
_F = {name: i for i, name in enumerate(FEATURE_NAMES)}


def _col(feats, name):
    return feats[..., _F[name]]


def _lf(condition, label):
    return jnp.where(condition, label, ABSTAIN)


def lf_spike_kurtosis(f):
    return _lf(_col(f, "kurtosis") > 10.0, Archetype.SPIKE)


def lf_spike_max_median(f):
    return _lf(_col(f, "max_to_median") > 20.0, Archetype.SPIKE)


def lf_spike_zero_burst(f):
    cond = (_col(f, "zero_fraction") > 0.5) & (_col(f, "max_to_mean") > 10.0)
    return _lf(cond, Archetype.SPIKE)


def lf_periodic_spectral(f):
    # trend guard: a linear ramp concentrates low-frequency power too
    cond = ((_col(f, "spectral_entropy") < 0.5)
            & (_col(f, "dominant_power_ratio") > 0.3)
            & (_col(f, "trend_r2") < 0.6))
    return _lf(cond, Archetype.PERIODIC)


def lf_periodic_autocorr(f):
    # trend guard: trending series have acf ~ 1 at every lag
    cond = ((_col(f, "acf_max") > 0.6) & (_col(f, "max_to_median") < 20.0)
            & (_col(f, "trend_r2") < 0.5))
    return _lf(cond, Archetype.PERIODIC)


def lf_periodic_peaks(f):
    cond = ((_col(f, "n_peaks") >= 2.0 / 60.0)
            & (_col(f, "acf_max") > 0.5)
            & (_col(f, "kurtosis") < 10.0)
            & (_col(f, "trend_r2") < 0.5))
    return _lf(cond, Archetype.PERIODIC)


def lf_ramp_trend(f):
    cond = (_col(f, "trend_r2") > 0.75) & (
        jnp.abs(_col(f, "trend_slope")) > 0.02)
    return _lf(cond, Archetype.RAMP)


def lf_ramp_halves(f):
    hr = _col(f, "half_ratio")
    cond = ((hr > 1.6) | (hr < 0.6)) & (_col(f, "trend_r2") > 0.5)
    return _lf(cond, Archetype.RAMP)


def lf_stationary_low_var(f):
    cond = ((_col(f, "cv") < 0.35)
            & (jnp.abs(_col(f, "trend_slope")) < 0.01)
            & (_col(f, "acf_max") < 0.6))
    return _lf(cond, Archetype.STATIONARY_NOISY)


def lf_stationary_no_structure(f):
    cond = ((_col(f, "spectral_entropy") > 0.85)
            & (_col(f, "kurtosis") < 3.0)
            & (_col(f, "max_to_median") < 5.0)
            & (_col(f, "trend_r2") < 0.5))
    return _lf(cond, Archetype.STATIONARY_NOISY)


LABELING_FUNCTIONS = [
    lf_spike_kurtosis, lf_spike_max_median, lf_spike_zero_burst,
    lf_periodic_spectral, lf_periodic_autocorr, lf_periodic_peaks,
    lf_ramp_trend, lf_ramp_halves,
    lf_stationary_low_var, lf_stationary_no_structure,
]
N_LFS = len(LABELING_FUNCTIONS)  # 10


def apply_lfs(features: jax.Array) -> jax.Array:
    """Run all LFs. features [..., 38] -> votes [..., N_LFS] in {-1, 0..3}."""
    votes = [lf(features).astype(jnp.int32) for lf in LABELING_FUNCTIONS]
    return jnp.stack(votes, axis=-1)


def majority_vote(votes: jax.Array):
    """Aggregate LF votes (paper: majority voting, agreement = confidence).

    Returns (labels [...], confidence [...], n_votes [...]).
    labels = -1 where every LF abstained. Ties break toward the
    rarer/riskier class (SPIKE > RAMP > PERIODIC > STATIONARY) by adding a
    tiny class-priority epsilon before the argmax.
    """
    counts = jnp.stack(
        [jnp.sum((votes == k).astype(jnp.int32), axis=-1)
         for k in range(N_CLASSES)], axis=-1).astype(jnp.float32)
    n_votes = jnp.sum(counts, axis=-1)
    # tie-break priority: SPIKE(1) > RAMP(3) > PERIODIC(0) > STATIONARY(2)
    prio = jnp.array([0.2, 0.3, 0.0, 0.25], jnp.float32) * 1e-3
    labels = jnp.argmax(counts + prio, axis=-1).astype(jnp.int32)
    labels = jnp.where(n_votes > 0, labels, ABSTAIN)
    confidence = jnp.max(counts, axis=-1) / jnp.maximum(n_votes, 1.0)
    confidence = jnp.where(n_votes > 0, confidence, 0.0)
    return labels, confidence, n_votes


@jax.jit
def weak_label(features: jax.Array):
    """features [..., 38] -> (labels, confidence, n_votes)."""
    return majority_vote(apply_lfs(features))
