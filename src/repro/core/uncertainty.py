"""Algorithm 1: uncertainty-aware scaling adjustment (paper §III.C.3).

Given confidence c in [0,1] and base parameters:
    m        = 1 + 0.5 (1 - c)          # margin multiplier
    cpu_adj  = cpu_target (1 - 0.2 (1 - c))
    cool_adj = cool_base * m
    rep_adj  = ceil(rep_base * m)

Lower confidence => more conservative: lower CPU target (more headroom),
longer cooldown, more minimum replicas.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class AdjustedParams(NamedTuple):
    target_cpu: jnp.ndarray
    cooldown_min: jnp.ndarray
    min_replicas: jnp.ndarray


def margin_multiplier(confidence):
    return 1.0 + 0.5 * (1.0 - confidence)


def adjust(confidence, target_cpu, cooldown_min, min_replicas) -> AdjustedParams:
    """Vectorized Algorithm 1. All args broadcastable jnp arrays."""
    c = jnp.clip(confidence, 0.0, 1.0)
    m = margin_multiplier(c)
    cpu_adj = target_cpu * (1.0 - 0.2 * (1.0 - c))
    cool_adj = cooldown_min * m
    rep_adj = jnp.ceil(min_replicas * m)
    return AdjustedParams(cpu_adj, cool_adj, rep_adj)
