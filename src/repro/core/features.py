"""Sliding-window feature extraction (38 features, paper §III.B.1).

Features are split into two groups:

* ``stat_time_features`` — 28 statistical + time-domain features. This is
  the compute hot-spot over 300K windows; a Pallas TPU kernel implements the
  same math (``repro.kernels.window_features``); this module is the oracle.
* ``freq_features`` — 10 frequency-domain features via ``jnp.fft`` (kept in
  XLA; TPU Pallas has no FFT primitive — see DESIGN.md).

All functions take ``windows`` of shape [..., W] (per-minute invocation
counts, W = 60 by default) and are jit/vmap friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-6

STAT_TIME_NAMES = [
    "mean", "std", "cv", "min", "max", "median", "q25", "q75", "iqr",
    "skewness", "kurtosis", "max_to_median", "max_to_mean", "zero_fraction",
    "range",
    "trend_slope", "trend_r2", "half_ratio",
    "acf_1", "acf_2", "acf_3", "acf_6", "acf_12",
    "acf_max", "acf_argmax", "mean_abs_diff", "max_abs_diff", "n_peaks",
]
FREQ_NAMES = [
    "spectral_entropy", "dominant_freq", "dominant_power_ratio",
    "top2_power_ratio", "low_band_power", "mid_band_power",
    "high_band_power", "spectral_centroid", "spectral_flatness",
    "spectral_rolloff",
]
FEATURE_NAMES = STAT_TIME_NAMES + FREQ_NAMES
N_FEATURES = len(FEATURE_NAMES)  # 38

ACF_MAX_LAG_LO, ACF_MAX_LAG_HI = 2, 30  # lag range searched for acf_max


def _acf(x, mean, var, lag):
    """Autocorrelation at a given lag (biased normalization by n)."""
    n = x.shape[-1]
    xc = x - mean[..., None]
    prod = xc[..., : n - lag] * xc[..., lag:]
    return jnp.sum(prod, axis=-1) / (n * var + EPS)


def stat_time_features(windows: jax.Array) -> jax.Array:
    """28 statistical + time-domain features. windows: [..., W] -> [..., 28]."""
    x = windows.astype(jnp.float32)
    n = x.shape[-1]
    t = jnp.arange(n, dtype=jnp.float32)

    mean = jnp.mean(x, axis=-1)
    var = jnp.mean((x - mean[..., None]) ** 2, axis=-1)
    std = jnp.sqrt(var)
    cv = std / (mean + EPS)
    xmin = jnp.min(x, axis=-1)
    xmax = jnp.max(x, axis=-1)

    xs = jnp.sort(x, axis=-1)

    def _quantile(q):
        # linear-interpolated quantile on the sorted window
        pos = q * (n - 1)
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.minimum(lo + 1, n - 1)
        w = pos - lo
        return xs[..., lo] * (1.0 - w) + xs[..., hi] * w

    median = _quantile(0.5)
    q25 = _quantile(0.25)
    q75 = _quantile(0.75)
    iqr = q75 - q25

    xc = x - mean[..., None]
    m2 = var
    m3 = jnp.mean(xc**3, axis=-1)
    m4 = jnp.mean(xc**4, axis=-1)
    skew = m3 / (m2**1.5 + EPS)
    kurt = m4 / (m2**2 + EPS) - 3.0  # Fisher (excess) kurtosis

    max_to_median = xmax / (median + EPS)
    max_to_mean = xmax / (mean + EPS)
    zero_frac = jnp.mean((x <= EPS).astype(jnp.float32), axis=-1)
    rng = xmax - xmin

    # OLS trend vs t, slope normalized by the window mean
    tbar = (n - 1) / 2.0
    tvar = jnp.mean((t - tbar) ** 2)
    cov_tx = jnp.mean((t - tbar) * xc, axis=-1)
    slope = cov_tx / tvar
    slope_norm = slope / (mean + EPS)
    r2 = (cov_tx**2) / (tvar * var + EPS)
    half = n // 2
    half_ratio = (jnp.mean(x[..., half:], axis=-1) + EPS) / (
        jnp.mean(x[..., :half], axis=-1) + EPS)

    acf1 = _acf(x, mean, var, 1)
    acf2 = _acf(x, mean, var, 2)
    acf3 = _acf(x, mean, var, 3)
    acf6 = _acf(x, mean, var, 6)
    acf12 = _acf(x, mean, var, 12)
    lags = list(range(ACF_MAX_LAG_LO, ACF_MAX_LAG_HI + 1))
    acfs = jnp.stack([_acf(x, mean, var, k) for k in lags], axis=-1)
    acf_max = jnp.max(acfs, axis=-1)
    acf_argmax = (jnp.argmax(acfs, axis=-1) + ACF_MAX_LAG_LO).astype(
        jnp.float32) / ACF_MAX_LAG_HI

    dx = x[..., 1:] - x[..., :-1]
    mean_abs_diff = jnp.mean(jnp.abs(dx), axis=-1) / (mean + EPS)
    max_abs_diff = jnp.max(jnp.abs(dx), axis=-1) / (mean + EPS)

    thresh = (mean + std)[..., None]
    mid, left, right = x[..., 1:-1], x[..., :-2], x[..., 2:]
    peaks = (mid > left) & (mid >= right) & (mid > thresh)
    n_peaks = jnp.sum(peaks.astype(jnp.float32), axis=-1) / n

    feats = jnp.stack(
        [mean, std, cv, xmin, xmax, median, q25, q75, iqr, skew, kurt,
         max_to_median, max_to_mean, zero_frac, rng,
         slope_norm, r2, half_ratio,
         acf1, acf2, acf3, acf6, acf12, acf_max, acf_argmax,
         mean_abs_diff, max_abs_diff, n_peaks], axis=-1)
    return feats


def freq_features(windows: jax.Array) -> jax.Array:
    """10 frequency-domain features via rFFT. windows: [..., W] -> [..., 10]."""
    x = windows.astype(jnp.float32)
    n = x.shape[-1]
    xc = x - jnp.mean(x, axis=-1, keepdims=True)
    spec = jnp.abs(jnp.fft.rfft(xc, axis=-1)) ** 2  # [..., n//2 + 1]
    power = spec[..., 1:]  # drop DC
    nb = power.shape[-1]
    total = jnp.sum(power, axis=-1) + EPS
    p = power / total[..., None]

    entropy = -jnp.sum(p * jnp.log(p + EPS), axis=-1) / jnp.log(float(nb))
    dom_idx = jnp.argmax(power, axis=-1)
    dom_freq = dom_idx.astype(jnp.float32) / nb
    dom_ratio = jnp.max(power, axis=-1) / total
    top2 = jnp.sum(jax.lax.top_k(power, 2)[0], axis=-1) / total

    idx = jnp.arange(nb)
    low = jnp.sum(jnp.where(idx < 5, power, 0.0), axis=-1) / total
    mid = jnp.sum(jnp.where((idx >= 5) & (idx < 15), power, 0.0), axis=-1) / total
    high = jnp.sum(jnp.where(idx >= 15, power, 0.0), axis=-1) / total

    centroid = jnp.sum(p * idx.astype(jnp.float32), axis=-1) / nb
    flatness = jnp.exp(jnp.mean(jnp.log(power + EPS), axis=-1)) / (
        jnp.mean(power, axis=-1) + EPS)
    cum = jnp.cumsum(p, axis=-1)
    rolloff = jnp.argmax((cum >= 0.85).astype(jnp.int32), axis=-1).astype(
        jnp.float32) / nb

    return jnp.stack([entropy, dom_freq, dom_ratio, top2, low, mid, high,
                      centroid, flatness, rolloff], axis=-1)


def extract_features(windows: jax.Array) -> jax.Array:
    """All 38 features. windows: [..., W] -> [..., 38]."""
    return jnp.concatenate(
        [stat_time_features(windows), freq_features(windows)], axis=-1)


extract_features_jit = jax.jit(extract_features)
