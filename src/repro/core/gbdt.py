"""Histogram-based gradient-boosted decision trees in pure JAX.

Stands in for the paper's LightGBM classifier (§III.C.1): multiclass
softmax objective, quantile-binned features (64 bins), depth-limited
level-order trees, class weights inversely proportional to frequency.

Everything is fixed-shape and jittable: the per-round tree build uses
segment-sum histograms over (node, feature, bin), vectorized split search,
and level-order node propagation.

Prediction traverses flattened *node tables*: at fit/load time the
[rounds, K, ...] level-order trees are reshaped once into contiguous
(feature, threshold, leaf) tables over a single round-major tree axis
(``NodeTables``), and ``predict_logits`` descends all N rows x T trees
in lockstep — one static-pattern column gather evaluates every
(tree, node) split comparison at once, then the level walk is pure
vector selects (``_descend``), no per-row dynamic gathers and no scan
over rounds. That is the identical layout and math the Pallas kernel in
``repro.kernels.gbdt_tables`` streams through VMEM (bit-exact by
construction); the host path additionally cache-blocks the tree axis
(``traverse_tables_chunked``, bit-identical — trees are independent).
The retained per-round scan (``predict_logits_scan``) is the parity
oracle; the two differ only in logit summation order (reshape-sum vs
sequential scan), so parity is bit-close, not bit-exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

EPS = 1e-12


class NodeTables(NamedTuple):
    """Level-order trees flattened over one round-major tree axis
    (T = rounds * K, tree t = round * K + class). Internal nodes are
    heap-indexed per level (node n at depth d lives at 2^d - 1 + n), so
    every (tree, node) split comparison evaluates in one shot and
    descending a level is a short select chain per (row, tree) pair."""
    feat: jax.Array    # [T, 2^depth - 1] int32 split feature ids
    thresh: jax.Array  # [T, 2^depth - 1] int32 split bins (right if >)
    leaf: jax.Array    # [T, 2^depth] f32 leaf values (lr folded in)


def node_tables(feat: jax.Array, thresh: jax.Array,
                leaf: jax.Array) -> NodeTables:
    """[rounds, K, ...] level-order trees -> contiguous NodeTables."""
    R, K, I = feat.shape
    L = leaf.shape[-1]
    return NodeTables(
        feat=jnp.asarray(feat, jnp.int32).reshape(R * K, I),
        thresh=jnp.asarray(thresh, jnp.int32).reshape(R * K, I),
        leaf=jnp.asarray(leaf, jnp.float32).reshape(R * K, L))


def _descend(bits: jax.Array, leaf: jax.Array) -> jax.Array:
    """bits [N, T, I] per-node go-right decisions, leaf [T, L] ->
    per-tree leaf values [N, T]. The walk is pure vector selects: at
    depth d the live node id picks this level's decision bit through a
    <= 2^d-way `jnp.where` chain — no lane-dynamic gather, which is
    exactly the form the Pallas node-table kernel vectorizes."""
    N, T, I = bits.shape
    L = leaf.shape[-1]
    depth = max(int(L).bit_length() - 1, 0)
    node = jnp.zeros((N, T), jnp.int32)
    for d in range(depth):
        base = (1 << d) - 1
        b = bits[:, :, base]
        for n in range(1, 1 << d):
            b = jnp.where(node == n, bits[:, :, base + n], b)
        node = node * 2 + b.astype(jnp.int32)
    return leaf[jnp.arange(T, dtype=jnp.int32)[None, :], node]


def traverse_tables(tables: NodeTables, xb: jax.Array) -> jax.Array:
    """Descend all trees for all rows: xb [N, F] int32 bins ->
    per-tree leaf values [N, T]. One static-pattern column gather
    evaluates every (tree, node) split comparison at once
    (`jnp.take(xb, feat.reshape(-1), axis=1)` — the index vector is
    shared by all rows, so XLA lowers it as a column permutation, not a
    per-row gather), then `_descend` walks the levels with vector
    selects. This lockstep form is what the kernel executes verbatim."""
    N = xb.shape[0]
    T, I = tables.feat.shape
    xv = jnp.take(xb, tables.feat.reshape(-1), axis=1)   # [N, T*I]
    bits = (xv > tables.thresh.reshape(-1)[None, :]).reshape(N, T, I)
    return _descend(bits, tables.leaf)


def traverse_tables_chunked(tables: NodeTables, xb: jax.Array,
                            tree_chunk: int | None = None) -> jax.Array:
    """`traverse_tables`, bit-identical, but `lax.scan`ned over chunks
    of the tree axis so the [N, tree_chunk * I] comparison plane stays
    cache-resident on CPU — the host path at large N (trees are
    independent, so chunking only reorders which tree is evaluated
    when, never any float op). `tree_chunk=None` picks the largest
    divisor of T that is <= 32."""
    N = xb.shape[0]
    T, I = tables.feat.shape
    L = tables.leaf.shape[-1]
    if tree_chunk is None:
        tree_chunk = next(tc for tc in range(min(T, 32), 0, -1)
                          if T % tc == 0)
    if tree_chunk >= T:
        return traverse_tables(tables, xb)
    tc = tree_chunk
    chunked = (tables.feat.reshape(T // tc, tc, I),
               tables.thresh.reshape(T // tc, tc, I),
               tables.leaf.reshape(T // tc, tc, L))

    def chunk(_, tabs):
        f, t, lv = tabs
        xv = jnp.take(xb, f.reshape(-1), axis=1)         # [N, tc*I]
        bits = (xv > t.reshape(-1)[None, :]).reshape(N, tc, I)
        return _, _descend(bits, lv)

    _, vals = jax.lax.scan(chunk, None, chunked)         # [C, N, tc]
    return jnp.moveaxis(vals, 0, 1).reshape(N, T)


def table_logits(base: jax.Array, tables: NodeTables, xb: jax.Array,
                 *, chunked: bool = False) -> jax.Array:
    """binned xb [N, F] -> logits [N, K] via the node tables
    (`chunked=True` takes the cache-blocked host traversal; both
    traversals are bit-identical). The per-class sum reassociates vs
    the round scan (reshape-sum), hence bit-close — not bit-exact —
    parity with `predict_logits_scan`."""
    trav = traverse_tables_chunked if chunked else traverse_tables
    vals = trav(tables, xb)                             # [N, T]
    K = base.shape[0]
    N, T = vals.shape
    return base + vals.reshape(N, T // K, K).sum(axis=1)


@dataclasses.dataclass(frozen=True)
class GBDTConfig:
    n_classes: int = 4
    n_rounds: int = 60
    depth: int = 4
    learning_rate: float = 0.25
    reg_lambda: float = 1.0
    n_bins: int = 64
    min_child_weight: float = 1e-3
    class_weighted: bool = True  # weights inversely proportional to frequency


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GBDTParams:
    """Trained ensemble. Trees are stored level-order.

    feat/thresh: [rounds, K, 2^depth - 1] split feature / bin (right if >).
    leaf:        [rounds, K, 2^depth] leaf values (learning rate folded in).
    bin_edges:   [F, n_bins - 1] quantile bin edges.
    base:        [K] initial logits (log priors).
    tables:      flattened NodeTables over the round-major tree axis —
                 derived from feat/thresh/leaf exactly once at
                 construction (fit / load / npz restore all route through
                 here), so neither the host `predict_logits` nor the
                 Pallas kernel pays the reshape per call.
    """

    feat: jax.Array
    thresh: jax.Array
    leaf: jax.Array
    bin_edges: jax.Array
    base: jax.Array
    tables: NodeTables | None = None

    def __post_init__(self):
        if self.tables is None:
            self.tables = node_tables(self.feat, self.thresh, self.leaf)

    @property
    def depth(self) -> int:
        return int(np.log2(self.leaf.shape[-1]) + 0.5)

    def tree_flatten(self):
        return ((self.feat, self.thresh, self.leaf, self.bin_edges,
                 self.base, self.tables), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def compute_bin_edges(X: np.ndarray, n_bins: int) -> np.ndarray:
    """Per-feature quantile bin edges. X [N, F] -> [F, n_bins - 1]."""
    qs = np.linspace(0.0, 1.0, n_bins + 1)[1:-1]
    edges = np.quantile(X, qs, axis=0).T.astype(np.float32)  # [F, B-1]
    # strictly increasing edges keep searchsorted well-behaved on ties
    edges += np.arange(n_bins - 1, dtype=np.float32) * 1e-9
    return edges


@jax.jit
def bin_features(X: jax.Array, edges: jax.Array) -> jax.Array:
    """X [N, F], edges [F, B-1] -> int32 bins [N, F] in [0, B-1]."""
    def per_feature(col, e):
        return jnp.searchsorted(e, col, side="right").astype(jnp.int32)
    return jax.vmap(per_feature, in_axes=(1, 0), out_axes=1)(X, edges)


def _build_tree(xb, g, h, *, depth, n_bins, reg_lambda, min_child_weight):
    """Greedy level-order tree for one class.

    xb [N, F] int32 bins; g, h [N] grad/hess. Returns
    (feat [2^depth-1], thresh [2^depth-1], leaf [2^depth], leaf_id [N]).
    """
    N, F = xb.shape
    B = n_bins
    node = jnp.zeros((N,), jnp.int32)  # level-local node id
    feats_out, thresh_out = [], []
    rows = jnp.arange(N)

    for d in range(depth):
        n_nodes = 1 << d
        # (node, feature, bin) histograms via one flat segment-sum
        flat_idx = (node[:, None] * F + jnp.arange(F)[None, :]) * B + xb
        seg = n_nodes * F * B
        hist_g = jax.ops.segment_sum(
            jnp.broadcast_to(g[:, None], (N, F)).reshape(-1),
            flat_idx.reshape(-1), num_segments=seg).reshape(n_nodes, F, B)
        hist_h = jax.ops.segment_sum(
            jnp.broadcast_to(h[:, None], (N, F)).reshape(-1),
            flat_idx.reshape(-1), num_segments=seg).reshape(n_nodes, F, B)

        GL = jnp.cumsum(hist_g, axis=-1)
        HL = jnp.cumsum(hist_h, axis=-1)
        GT, HT = GL[..., -1:], HL[..., -1:]
        GR, HR = GT - GL, HT - HL
        gain = (GL**2 / (HL + reg_lambda) + GR**2 / (HR + reg_lambda)
                - GT**2 / (HT + reg_lambda))
        valid = ((HL >= min_child_weight) & (HR >= min_child_weight)
                 & (jnp.arange(B) < B - 1))
        gain = jnp.where(valid, gain, -jnp.inf)

        flat_gain = gain.reshape(n_nodes, F * B)
        best = jnp.argmax(flat_gain, axis=-1)           # [n_nodes]
        best_gain = jnp.take_along_axis(flat_gain, best[:, None], -1)[:, 0]
        bf = (best // B).astype(jnp.int32)               # split feature
        bb = (best % B).astype(jnp.int32)                # split bin
        # nodes with no valid split: degenerate split (everything left)
        no_split = ~jnp.isfinite(best_gain)
        bf = jnp.where(no_split, 0, bf)
        bb = jnp.where(no_split, B - 1, bb)              # x <= B-1 always

        feats_out.append(bf)
        thresh_out.append(bb)

        go_right = xb[rows, bf[node]] > bb[node]
        node = node * 2 + go_right.astype(jnp.int32)

    n_leaves = 1 << depth
    sum_g = jax.ops.segment_sum(g, node, num_segments=n_leaves)
    sum_h = jax.ops.segment_sum(h, node, num_segments=n_leaves)
    leaf = -sum_g / (sum_h + reg_lambda)
    return (jnp.concatenate(feats_out), jnp.concatenate(thresh_out),
            leaf, node)


@partial(jax.jit, static_argnames=("cfg",))
def _boost_round(xb, y_onehot, w, logits, cfg: GBDTConfig):
    """One boosting round: K trees (one per class). Returns new logits
    and the round's (feat [K, 2^d -1], thresh, leaf [K, 2^d])."""
    p = jax.nn.softmax(logits, axis=-1)
    G = (p - y_onehot) * w[:, None]
    H = jnp.maximum(p * (1.0 - p), 1e-6) * w[:, None]

    build = partial(_build_tree, depth=cfg.depth, n_bins=cfg.n_bins,
                    reg_lambda=cfg.reg_lambda,
                    min_child_weight=cfg.min_child_weight)
    feat, thresh, leaf, leaf_id = jax.vmap(
        lambda g, h: build(xb, g, h), in_axes=1, out_axes=0)(G, H)
    leaf = leaf * cfg.learning_rate
    delta = jax.vmap(lambda lv, li: lv[li], in_axes=0, out_axes=1)(
        leaf, leaf_id)  # [N, K]
    return logits + delta, (feat, thresh, leaf)


def fit(X: np.ndarray, y: np.ndarray, cfg: GBDTConfig = GBDTConfig(),
        *, verbose: bool = False) -> GBDTParams:
    """Train. X [N, F] float, y [N] int in [0, K)."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int32)
    N, F = X.shape
    K = cfg.n_classes

    edges = compute_bin_edges(X, cfg.n_bins)
    xb = bin_features(jnp.asarray(X), jnp.asarray(edges))

    counts = np.bincount(y, minlength=K).astype(np.float64)
    priors = np.maximum(counts, 1.0) / max(N, 1)
    base = jnp.asarray(np.log(priors), jnp.float32)
    if cfg.class_weighted:
        w_cls = N / (K * np.maximum(counts, 1.0))
    else:
        w_cls = np.ones(K)
    w = jnp.asarray(w_cls, jnp.float32)[jnp.asarray(y)]
    y_onehot = jax.nn.one_hot(jnp.asarray(y), K, dtype=jnp.float32)

    logits = jnp.broadcast_to(base, (N, K))
    feats, threshs, leaves = [], [], []
    for r in range(cfg.n_rounds):
        logits, (f, t, l) = _boost_round(xb, y_onehot, w, logits, cfg)
        feats.append(f), threshs.append(t), leaves.append(l)
        if verbose and (r % 10 == 0 or r == cfg.n_rounds - 1):
            acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))
            print(f"  round {r:3d}  train_acc={acc:.4f}")

    return GBDTParams(
        feat=jnp.stack(feats), thresh=jnp.stack(threshs),
        leaf=jnp.stack(leaves), bin_edges=jnp.asarray(edges), base=base)


@jax.jit
def predict_logits(params: GBDTParams, X: jax.Array) -> jax.Array:
    """X [N, F] -> logits [N, K] via the flattened node tables (all rows
    x trees descend level-order in lockstep; no scan over rounds)."""
    xb = bin_features(X.astype(jnp.float32), params.bin_edges)
    tables = (params.tables if params.tables is not None
              else node_tables(params.feat, params.thresh, params.leaf))
    return table_logits(params.base, tables, xb, chunked=True)


@jax.jit
def predict_logits_scan(params: GBDTParams, X: jax.Array) -> jax.Array:
    """The retained per-round scan (one `apply_tree` walk per round):
    the parity oracle for the table path and the kernel, and the host
    baseline bench_classification measures the table speedup against."""
    xb = bin_features(X.astype(jnp.float32), params.bin_edges)
    N = X.shape[0]
    depth = params.depth
    rows = jnp.arange(N)

    def apply_tree(feat, thresh, leaf):
        node = jnp.zeros((N,), jnp.int32)
        for d in range(depth):
            base = (1 << d) - 1
            f = feat[base + node]
            t = thresh[base + node]
            node = node * 2 + (xb[rows, f] > t).astype(jnp.int32)
        return leaf[node]  # [N]

    def per_round(logits, tree):
        feat, thresh, leaf = tree
        delta = jax.vmap(apply_tree, in_axes=0, out_axes=1)(
            feat, thresh, leaf)  # [N, K]
        return logits + delta, None

    logits0 = jnp.broadcast_to(params.base, (N, params.base.shape[0]))
    logits, _ = jax.lax.scan(
        per_round, logits0, (params.feat, params.thresh, params.leaf))
    return logits


def predict_proba(params: GBDTParams, X: jax.Array) -> jax.Array:
    return jax.nn.softmax(predict_logits(params, X), axis=-1)


def predict(params: GBDTParams, X: jax.Array) -> jax.Array:
    return jnp.argmax(predict_logits(params, X), axis=-1)


def save(params: GBDTParams, path: str) -> None:
    np.savez(path, feat=np.asarray(params.feat),
             thresh=np.asarray(params.thresh), leaf=np.asarray(params.leaf),
             bin_edges=np.asarray(params.bin_edges),
             base=np.asarray(params.base))


def load(path: str) -> GBDTParams:
    z = np.load(path)
    return GBDTParams(feat=jnp.asarray(z["feat"]),
                      thresh=jnp.asarray(z["thresh"]),
                      leaf=jnp.asarray(z["leaf"]),
                      bin_edges=jnp.asarray(z["bin_edges"]),
                      base=jnp.asarray(z["base"]))
