"""Holt-Winters (triple exponential smoothing) in JAX.

Used by the PERIODIC archetype strategy (paper Table III) and by the
Generic Predictive baseline (paper §IV.C: uniform Holt-Winters with a
15-minute prediction horizon).

Two forms are provided:

* ``hw_step`` — one online update, usable inside the cluster simulator's
  lax.scan (state lives in the controller carry).
* ``hw_smooth`` — full-series smoothing with one-step-ahead forecasts,
  used for offline backtests. This sequential recurrence is also
  implemented as a Pallas TPU kernel (``repro.kernels.holt_winters``);
  this function is its oracle.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class HWState(NamedTuple):
    level: jax.Array    # []
    trend: jax.Array    # []
    season: jax.Array   # [period]
    t: jax.Array        # [] int32, current phase


def hw_init(period: int, y0: float | jax.Array = 0.0) -> HWState:
    y0 = jnp.asarray(y0, jnp.float32)
    return HWState(level=y0, trend=jnp.float32(0.0),
                   season=jnp.zeros((period,), jnp.float32),
                   t=jnp.int32(0))


def hw_step(state: HWState, y: jax.Array, *, alpha=0.1, beta=0.01,
            gamma=0.3) -> HWState:
    """Additive-seasonal Holt-Winters online update with observation y."""
    period = state.season.shape[0]
    phase = state.t % period
    s_t = state.season[phase]
    level_new = alpha * (y - s_t) + (1.0 - alpha) * (state.level + state.trend)
    trend_new = beta * (level_new - state.level) + (1.0 - beta) * state.trend
    season_new = state.season.at[phase].set(
        gamma * (y - level_new) + (1.0 - gamma) * s_t)
    return HWState(level_new, trend_new, season_new, state.t + 1)


def hw_forecast(state: HWState, horizon: int) -> jax.Array:
    """h-step-ahead point forecast from the current state."""
    period = state.season.shape[0]
    phase = (state.t + horizon - 1) % period
    return state.level + horizon * state.trend + state.season[phase]


def hw_forecast_max(state: HWState, horizon: int) -> jax.Array:
    """Max forecast over the next `horizon` steps (for peak pre-scaling)."""
    hs = jnp.arange(1, horizon + 1)
    period = state.season.shape[0]
    phases = (state.t + hs - 1) % period
    preds = state.level + hs.astype(jnp.float32) * state.trend \
        + state.season[phases]
    return jnp.max(preds)


_SMOOTH_BUCKET = 256     # series lengths round up to this compile bucket


@partial(jax.jit, static_argnames=("period",), donate_argnums=(0,))
def _hw_smooth_padded(y: jax.Array, alpha, beta, gamma, *,
                      period: int) -> jax.Array:
    def scan_one(series):
        def body(state, yt):
            pred = hw_forecast(state, 1)
            nxt = hw_step(state, yt, alpha=alpha, beta=beta, gamma=gamma)
            return nxt, pred
        init = hw_init(period, series[0])
        _, preds = jax.lax.scan(body, init, series)
        return preds

    return jax.vmap(scan_one)(y)


def hw_smooth(y: jax.Array, *, period: int = 60, alpha=0.1, beta=0.01,
              gamma=0.3) -> jax.Array:
    """One-step-ahead forecasts over a whole series.

    y [..., T] -> forecasts [..., T] where forecasts[..., t] is the
    prediction of y[..., t] made at time t-1. Vectorizes over leading axes.

    The recurrence is causal, so the series is zero-padded up to the next
    ``_SMOOTH_BUCKET`` multiple before entering the jitted scan: backtests
    over mixed-length traces inside one bucket reuse a single compilation
    (the padded scratch buffer is donated). `period` stays a static arg of
    the inner jit; alpha/beta/gamma are traced scalars.
    """
    T = y.shape[-1]
    pad_t = -(-T // _SMOOTH_BUCKET) * _SMOOTH_BUCKET
    flat = jnp.asarray(y, jnp.float32).reshape((-1, T))
    padded = jnp.pad(flat, ((0, 0), (0, pad_t - T)))
    out = _hw_smooth_padded(padded, jnp.float32(alpha), jnp.float32(beta),
                            jnp.float32(gamma), period=period)
    return out[:, :T].reshape(y.shape)


def linear_trend_forecast(history: jax.Array, horizon: int) -> jax.Array:
    """RAMP strategy: OLS trend extrapolation `horizon` steps ahead.

    history [..., T] -> scalar forecast [...]. Clipped at zero.
    """
    x = history.astype(jnp.float32)
    n = x.shape[-1]
    t = jnp.arange(n, dtype=jnp.float32)
    tbar = (n - 1) / 2.0
    tvar = jnp.mean((t - tbar) ** 2)
    mean = jnp.mean(x, axis=-1)
    slope = jnp.mean((t - tbar) * (x - mean[..., None]), axis=-1) / tvar
    return jnp.maximum(mean + slope * ((n - 1) - tbar + horizon), 0.0)
