"""End-to-end AAPA pipeline: traces -> windows -> features -> weak labels
-> GBDT -> beta calibration -> deployable classifier closure.

This is the glue the paper's Figure 1 describes: the feature-extraction
pipeline feeds the weak-supervision labeler, the classifier trains on the
weak labels (days 1-9), calibrates on validation days (10-11), and the
resulting `classify` closure plugs into ``aapa_controller``.

Dataset construction lives in ``repro.aapaset`` (chunked jitted build,
content-addressed shard cache, named registry); this module trains
classifiers from those datasets — either directly from traces
(``train_aapa``) or from a named, hash-pinned artifact
(``train_from_loader`` / ``train_classifier``).
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration, gbdt
from repro.data import windows as W
from repro.data.azure_synth import TraceSet


@dataclasses.dataclass
class TrainedAAPA:
    params: gbdt.GBDTParams
    cal: calibration.BetaCalibration
    train_acc: float
    val_acc: float
    test_acc: float
    label_dist: np.ndarray     # weak-label distribution over 4 classes
    n_windows: int
    fit_seconds: float
    dataset_id: str = ""       # "name-hash12" when trained from an artifact

    def make_classify(self) -> Callable:
        """Returns classify(features [38]) -> (class int32, confidence)."""
        params, cal = self.params, self.cal

        def classify(feats: jax.Array):
            logits = gbdt.predict_logits(params, feats[None, :])
            probs = jax.nn.softmax(logits, axis=-1)
            calp = calibration.calibrate(cal, probs)[0]
            return (jnp.argmax(calp).astype(jnp.int32),
                    jnp.max(calp).astype(jnp.float32))

        return classify

    def save(self, path: str | pathlib.Path) -> None:
        """Single-file npz round-trip (classifier + calibration + card)."""
        p = self.params
        np.savez(
            path,
            feat=np.asarray(p.feat), thresh=np.asarray(p.thresh),
            leaf=np.asarray(p.leaf), bin_edges=np.asarray(p.bin_edges),
            base=np.asarray(p.base),
            cal_a_raw=np.asarray(self.cal.a_raw),
            cal_b_raw=np.asarray(self.cal.b_raw),
            cal_c=np.asarray(self.cal.c),
            label_dist=np.asarray(self.label_dist),
            scalars=np.array([self.train_acc, self.val_acc, self.test_acc,
                              float(self.n_windows), self.fit_seconds],
                             np.float64),
            dataset_id=np.array(self.dataset_id))

    @classmethod
    def load(cls, path: str | pathlib.Path) -> "TrainedAAPA":
        with np.load(path) as z:
            return cls._from_npz(z)

    @classmethod
    def _from_npz(cls, z) -> "TrainedAAPA":
        params = gbdt.GBDTParams(
            feat=jnp.asarray(z["feat"]), thresh=jnp.asarray(z["thresh"]),
            leaf=jnp.asarray(z["leaf"]),
            bin_edges=jnp.asarray(z["bin_edges"]),
            base=jnp.asarray(z["base"]))
        cal = calibration.BetaCalibration(
            a_raw=jnp.asarray(z["cal_a_raw"]),
            b_raw=jnp.asarray(z["cal_b_raw"]),
            c=jnp.asarray(z["cal_c"]))
        s = z["scalars"]
        return cls(params=params, cal=cal, train_acc=float(s[0]),
                   val_acc=float(s[1]), test_acc=float(s[2]),
                   label_dist=z["label_dist"], n_windows=int(s[3]),
                   fit_seconds=float(s[4]),
                   dataset_id=str(z["dataset_id"]))


def featurize_and_label(ds: W.WindowDataset, batch: int = 8192):
    """Extract 38 features + weak labels for every window.

    Thin wrapper over the chunked jitted AAPAset builder (one compile
    per chunk shape) — kept for callers that work from a raw
    ``WindowDataset`` rather than a named artifact. Always uses the ref
    feature math (the legacy contract: identical bytes on every
    backend); artifact builds choose their feature path explicitly via
    ``DatasetConfig.feature_path``.
    """
    from repro.aapaset.build import featurize_windows
    feats, labels, confs, _ = featurize_windows(ds.windows, chunk=batch,
                                                use_kernel=False)
    return feats, labels, confs


def _fit_classifier(X, y, split_masks, cfg: gbdt.GBDTConfig,
                    *, verbose: bool,
                    dataset_id: str = "") -> TrainedAAPA:
    """Shared trainer: fit on train mask, calibrate on val, report accs.

    `X`/`y` must already be restricted to labeled windows (y >= 0)."""
    t0 = time.time()
    params = gbdt.fit(X[split_masks["train"]], y[split_masks["train"]],
                      cfg, verbose=verbose)
    fit_s = time.time() - t0

    def acc(m):
        if m.sum() == 0:
            return float("nan")
        pred = np.asarray(gbdt.predict(params, jnp.asarray(X[m])))
        return float((pred == y[m]).mean())

    probs_val = np.asarray(gbdt.predict_proba(
        params, jnp.asarray(X[split_masks["val"]])))
    cal = calibration.fit(probs_val, y[split_masks["val"]])

    dist = np.bincount(y, minlength=4) / max(len(y), 1)
    return TrainedAAPA(params=params, cal=cal,
                       train_acc=acc(split_masks["train"]),
                       val_acc=acc(split_masks["val"]),
                       test_acc=acc(split_masks["test"]),
                       label_dist=dist, n_windows=len(y),
                       fit_seconds=fit_s, dataset_id=dataset_id)


def train_aapa(traces: TraceSet, cfg: gbdt.GBDTConfig = gbdt.GBDTConfig(),
               *, verbose: bool = False) -> TrainedAAPA:
    """Train directly from a TraceSet (no artifact cache)."""
    ds = W.make_windows(traces)
    split = W.default_day_split(ds, traces.n_days)
    X, y, conf = featurize_and_label(ds)

    labeled = y >= 0  # drop windows where every LF abstained
    masks = {k: m[labeled] for k, m in split.items()}
    return _fit_classifier(X[labeled], y[labeled], masks, cfg,
                           verbose=verbose)


def train_from_loader(loader, cfg: gbdt.GBDTConfig = gbdt.GBDTConfig(),
                      *, verbose: bool = False) -> TrainedAAPA:
    """Train from a built AAPAset artifact via its loader: the classifier
    the `aapa`/`hybrid` policies consume names the exact dataset it was
    trained on (``trained.dataset_id``)."""
    idx = loader.split_indices(None)                 # all labeled rows
    X = loader.data.features[idx]
    y = loader.data.labels[idx]
    split = loader.data.split[idx]
    from repro.aapaset.build import SPLIT_NAMES
    masks = {name: split == code
             for code, name in enumerate(SPLIT_NAMES)}
    return _fit_classifier(X, y, masks, cfg, verbose=verbose,
                           dataset_id=loader.dataset_id)


# Bump whenever gbdt.fit / calibration.fit / _fit_classifier change in a
# way that alters trained outputs: it keys the classifier npz cache the
# same way aapaset's SCHEMA_VERSION keys dataset artifacts.
CLASSIFIER_VERSION = 1


def train_classifier(dataset: str = "aapaset_ci",
                     cfg: gbdt.GBDTConfig = gbdt.GBDTConfig(),
                     *, root=None, cache: bool = True,
                     verbose: bool = False,
                     loader_factory=None) -> TrainedAAPA:
    """Build-or-load a named dataset, then train-or-load the classifier.

    The trained model is cached as npz inside the dataset artifact dir,
    keyed by (CLASSIFIER_VERSION, GBDT config), so examples and
    benchmarks reuse one fit. On a classifier-cache hit no dataset shard
    is touched; on a miss the dataset comes from `loader_factory()` when
    given (lets callers share one loaded artifact) else is loaded fresh.
    """
    import os

    from repro.aapaset import manifest as MF
    from repro.aapaset import registry
    from repro.aapaset.loader import AAPAsetLoader

    root = MF.DEFAULT_ROOT if root is None else root
    key = MF.hash_json({"v": CLASSIFIER_VERSION,
                        "gbdt": dataclasses.asdict(cfg)}, n=8)
    path = MF.artifact_dir(registry.get(dataset), root) \
        / f"classifier-{key}.npz"
    if cache and path.exists():       # skip loading the dataset shards
        return TrainedAAPA.load(path)
    loader = loader_factory() if loader_factory is not None \
        else AAPAsetLoader.from_name(dataset, root)
    trained = train_from_loader(loader, cfg, verbose=verbose)
    # a dataset too small for a test split (n_days <= 2) yields
    # test_acc = NaN by design — return it, but never cache it
    if cache and np.isfinite(trained.test_acc):
        path.parent.mkdir(parents=True, exist_ok=True)
        MF.sweep_stale_tmp(path.parent, f".tmp-*-{path.name}")
        tmp = path.with_name(f".tmp-{os.getpid()}-{path.name}")
        trained.save(tmp)
        tmp.replace(path)             # atomic: never a half-written cache
    return trained
