"""End-to-end AAPA pipeline: traces -> windows -> features -> weak labels
-> GBDT -> beta calibration -> deployable classifier closure.

This is the glue the paper's Figure 1 describes: the feature-extraction
pipeline feeds the weak-supervision labeler, the classifier trains on the
weak labels (days 1-9), calibrates on validation days (10-11), and the
resulting `classify` closure plugs into ``aapa_controller``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import calibration, gbdt
from repro.core import features as F
from repro.core import labeling
from repro.data import windows as W
from repro.data.azure_synth import TraceSet


@dataclasses.dataclass
class TrainedAAPA:
    params: gbdt.GBDTParams
    cal: calibration.BetaCalibration
    train_acc: float
    val_acc: float
    test_acc: float
    label_dist: np.ndarray     # weak-label distribution over 4 classes
    n_windows: int
    fit_seconds: float

    def make_classify(self) -> Callable:
        """Returns classify(features [38]) -> (class int32, confidence)."""
        params, cal = self.params, self.cal

        def classify(feats: jax.Array):
            logits = gbdt.predict_logits(params, feats[None, :])
            probs = jax.nn.softmax(logits, axis=-1)
            calp = calibration.calibrate(cal, probs)[0]
            return (jnp.argmax(calp).astype(jnp.int32),
                    jnp.max(calp).astype(jnp.float32))

        return classify


def featurize_and_label(ds: W.WindowDataset, batch: int = 65536):
    """Extract 38 features + weak labels for every window (batched)."""
    feats, labels, confs = [], [], []
    for i in range(0, len(ds), batch):
        wb = jnp.asarray(ds.windows[i:i + batch])
        fb = F.extract_features_jit(wb)
        lb, cb, _ = labeling.weak_label(fb)
        feats.append(np.asarray(fb))
        labels.append(np.asarray(lb))
        confs.append(np.asarray(cb))
    return (np.concatenate(feats), np.concatenate(labels),
            np.concatenate(confs))


def train_aapa(traces: TraceSet, cfg: gbdt.GBDTConfig = gbdt.GBDTConfig(),
               *, verbose: bool = False) -> TrainedAAPA:
    ds = W.make_windows(traces)
    if traces.n_days >= 14:   # paper split: 1-9 / 10-11 / 12-14
        split = W.day_split(ds)
    else:                     # proportional split for smaller runs
        n = traces.n_days
        t_end = max(int(n * 9 / 14), 1)
        v_end = max(int(n * 11 / 14), t_end + 1)
        split = W.day_split(ds, train_days=(1, t_end),
                            val_days=(t_end + 1, v_end),
                            test_days=(v_end + 1, n))
    X, y, _ = featurize_and_label(ds)

    labeled = y >= 0  # drop windows where every LF abstained
    masks = {k: m & labeled for k, m in split.items()}

    t0 = time.time()
    params = gbdt.fit(X[masks["train"]], y[masks["train"]], cfg,
                      verbose=verbose)
    fit_s = time.time() - t0

    def acc(m):
        if m.sum() == 0:
            return float("nan")
        pred = np.asarray(gbdt.predict(params, jnp.asarray(X[m])))
        return float((pred == y[m]).mean())

    probs_val = np.asarray(gbdt.predict_proba(params,
                                              jnp.asarray(X[masks["val"]])))
    cal = calibration.fit(probs_val, y[masks["val"]])

    dist = np.bincount(y[labeled], minlength=4) / max(labeled.sum(), 1)
    return TrainedAAPA(params=params, cal=cal,
                       train_acc=acc(masks["train"]),
                       val_acc=acc(masks["val"]), test_acc=acc(masks["test"]),
                       label_dist=dist, n_windows=int(labeled.sum()),
                       fit_seconds=fit_s)
