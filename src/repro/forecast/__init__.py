"""Unified forecasting + uncertainty subsystem for the scaling control
plane: the `Forecaster` protocol (`api`), built-in models (`models`),
named factories with per-archetype defaults (`registry`), split-conformal
intervals (`conformal`), and batched offline backtests (`backtest`).

Confidence flows forecaster -> conformal band -> Algorithm 1
(``repro.core.uncertainty.adjust``) -> policy; see README.
"""
from repro.forecast import backtest, conformal, registry  # noqa: F401
from repro.forecast.api import (Forecaster, FState, Interval,  # noqa: F401
                                interval_confidence, make_forecaster)

__all__ = ["Forecaster", "FState", "Interval", "interval_confidence",
           "make_forecaster", "backtest", "conformal", "registry"]
