"""Backend-agnostic forecasting protocol for the scaling control plane.

Mirrors ``repro.scaling.api``: a `Forecaster` is a named bundle of pure,
jittable closures, so the same object runs compiled inside the cluster
simulator's `lax.scan` (state carried in the controller carry) and
eagerly inside the serving-engine adapter:

    init()                       -> state
    update(state, y)             -> state        # observe one sample
    forecast(state, horizon)     -> Interval(point, lo, hi)
    smooth(y [..., T])           -> [..., T]     # offline one-step backtest

`forecast` returns the *peak* point forecast over the next `horizon`
steps (what pre-scaling wants) plus an uncertainty band. The native band
comes from an EWMA of one-step absolute residuals tracked inside every
state (`FState.resid`) and widens with sqrt(horizon); split-conformal
calibration (``repro.forecast.conformal``) replaces it with a
distribution-free one. Interval width is the confidence signal the
control plane feeds into Algorithm 1 (``repro.core.uncertainty.adjust``)
via `interval_confidence`.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

RESID_RHO = 0.05         # EWMA rate for the one-step residual scale
NATIVE_Z = 1.64          # ~90% band under a Gaussian residual model
EPSF = 1e-9
MIN_CONF_SCALE = 1.0     # one request/min: arrival counts resolve no finer


class Interval(NamedTuple):
    """Point forecast with an uncertainty band (lo <= point <= hi)."""
    point: jax.Array
    lo: jax.Array
    hi: jax.Array


class FState(NamedTuple):
    """Uniform forecaster carry: model state + residual-scale EWMA."""
    inner: Any
    resid: jax.Array     # f32 EWMA of |one-step-ahead error|


class Forecaster(NamedTuple):
    """Pluggable forecaster (all functions jittable)."""
    name: str
    init: Callable[[], "FState"]
    update: Callable[["FState", jax.Array], "FState"]
    forecast: Callable[["FState", int], Interval]
    smooth: Callable[[jax.Array], jax.Array]


def interval_confidence(iv: Interval, scale: jax.Array | None = None, *,
                        floor: float = MIN_CONF_SCALE):
    """Map an interval's relative width to a confidence c in [0, 1].

    c = scale / (scale + width): 1 for a zero-width band, monotonically
    decreasing as the band widens. `scale` defaults to the point forecast
    (relative-width semantics); pass the conformal band's trace scale for
    a calibration-consistent signal.

    The scale is floored at `floor` (default `MIN_CONF_SCALE`, one
    request/min — the resolution of arrival counts). Without the floor an
    idle/near-zero trace collapses the scale to ~0 and c -> width/(0 +
    width) ~ 0 however narrow the band is, so AAPA's forecast-confidence
    signal forced maximally conservative Algorithm-1 adjustments exactly
    when the trace was trivially predictable. Pass the tracked
    residual/trace scale as `floor` to tighten it further.
    """
    width = jnp.maximum(iv.hi - iv.lo, 0.0)
    s = jnp.maximum(iv.point if scale is None else scale,
                    jnp.maximum(floor, EPSF))
    return s / (s + width)


def make_forecaster(name: str, *, init_inner, update_inner, point_fn,
                    smooth_fn=None, z: float = NATIVE_Z) -> Forecaster:
    """Assemble a Forecaster from model-specific pieces.

    ``init_inner() -> inner``, ``update_inner(inner, y) -> inner``,
    ``point_fn(inner, horizon) -> peak point forecast``. Residual
    tracking, the native interval, and (unless `smooth_fn` is given) the
    scan-based offline backtest are shared here.
    """

    def init() -> FState:
        return FState(inner=init_inner(), resid=jnp.float32(0.0))

    def update(state: FState, y) -> FState:
        y = jnp.asarray(y, jnp.float32)
        pred1 = point_fn(state.inner, 1)
        resid = state.resid + RESID_RHO * (jnp.abs(y - pred1) - state.resid)
        return FState(inner=update_inner(state.inner, y), resid=resid)

    def forecast(state: FState, horizon: int) -> Interval:
        point = point_fn(state.inner, horizon)
        half = z * state.resid * jnp.sqrt(jnp.float32(horizon))
        return Interval(point=point,
                        lo=jnp.maximum(point - half, 0.0),
                        hi=point + half)

    def smooth(y: jax.Array) -> jax.Array:
        """[..., T] -> one-step-ahead point forecasts [..., T]."""
        y = jnp.asarray(y, jnp.float32)     # lists/tuples have no .shape
        if smooth_fn is not None:
            return smooth_fn(y)

        def scan_one(series):
            def body(st, yt):
                return update(st, yt), point_fn(st.inner, 1)
            _, preds = jax.lax.scan(body, init(), series)
            return preds

        flat = y.reshape((-1, y.shape[-1]))
        return jax.vmap(scan_one)(flat).reshape(y.shape)

    return Forecaster(name, init, update, forecast, smooth)
