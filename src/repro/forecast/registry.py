"""Named forecaster factories with per-archetype defaults.

    from repro.forecast import registry
    fcst = registry.make("holt_winters", period=1440)
    name = registry.for_archetype(Archetype.RAMP)     # -> "linear_trend"

Mirrors ``repro.scaling.registry``: policies resolve forecasters here by
name, so adding a forecasting model is one `register(...)` call and it is
immediately usable from every policy, the batched simulator, and the
benchmarks (see README "add your own forecaster").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core.archetypes import Archetype
from repro.forecast import models as Mo
from repro.forecast.api import Forecaster


@dataclasses.dataclass(frozen=True)
class ForecasterSpec:
    name: str
    factory: Callable[..., Forecaster]   # factory(**hyper) -> Forecaster
    defaults: dict[str, Any]
    description: str = ""


_REGISTRY: dict[str, ForecasterSpec] = {}


def register(name: str, factory: Callable[..., Forecaster], *,
             defaults: dict[str, Any] | None = None,
             description: str = "") -> None:
    if name in _REGISTRY:
        raise ValueError(f"forecaster {name!r} already registered")
    _REGISTRY[name] = ForecasterSpec(name, factory, dict(defaults or {}),
                                     description)


def available() -> list[str]:
    return sorted(_REGISTRY)


def spec(name: str) -> ForecasterSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown forecaster {name!r}; "
                       f"available: {available()}") from None


def make(name: str | Forecaster, **overrides) -> Forecaster:
    """Build a registered forecaster with defaults + overrides applied.
    A `Forecaster` instance passes through unchanged (so every API that
    resolves names also accepts pre-built forecasters)."""
    if isinstance(name, Forecaster):
        if overrides:
            raise TypeError("cannot override hyperparameters of a "
                            "pre-built Forecaster instance")
        return name
    sp = spec(name)
    kw = dict(sp.defaults)
    unknown = set(overrides) - set(kw)
    if unknown:
        raise TypeError(f"forecaster {name!r} has no hyperparameters "
                        f"{sorted(unknown)}; accepts {sorted(kw)}")
    kw.update(overrides)
    return sp.factory(**kw)


# Per-archetype defaults (paper Table III strategy column): PERIODIC
# backtests best under seasonal smoothing, RAMP under trend
# extrapolation, SPIKE/STATIONARY under a conservative level model.
ARCHETYPE_DEFAULT: dict[Archetype, str] = {
    Archetype.PERIODIC: "holt_winters",
    Archetype.SPIKE: "ewma",
    Archetype.STATIONARY_NOISY: "ewma",
    Archetype.RAMP: "linear_trend",
}


def for_archetype(arch: Archetype | int) -> str:
    return ARCHETYPE_DEFAULT[Archetype(int(arch))]


# ------------------------------------------------------ built-in catalog ----
register(
    "holt_winters", Mo.holt_winters_forecaster,
    defaults=dict(period=60, alpha=0.1, beta=0.01, gamma=0.3),
    description="Additive-seasonal triple exponential smoothing; offline "
                "backtests dispatch to the Pallas kernel on TPU.")

register(
    "linear_trend", Mo.linear_trend_forecaster,
    defaults=dict(window=30),
    description="Sliding-window OLS trend extrapolation (RAMP strategy).")

register(
    "seasonal_naive", Mo.seasonal_naive_forecaster,
    defaults=dict(period=60),
    description="Repeat the value one period ago.")

register(
    "ewma", Mo.ewma_forecaster,
    defaults=dict(alpha=0.3),
    description="Exponentially weighted level, flat at every horizon.")
