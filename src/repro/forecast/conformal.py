"""Split-conformal prediction intervals from rolling backtest residuals.

Distribution-free uncertainty for any registered forecaster: run the
offline one-step backtest (`forecaster.smooth`) on a calibration split,
take the ceil((n+1)*alpha)/n empirical quantile of the absolute
residuals, and use it as the interval half-width. Under exchangeable
residuals the interval covers the next observation with probability
>= alpha (Vovk et al.; the coverage test in tests/test_forecast.py checks
the empirical rate on synthetic Azure traces).

The calibrated width is the control plane's confidence signal: `wrap`
returns a Forecaster whose intervals carry the conformal band, and
`confidence` maps relative band width into the c in [0, 1] that
Algorithm 1 (``repro.core.uncertainty.adjust``) consumes — wide bands
(high forecast uncertainty) mean low confidence and conservative scaling.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.forecast.api import EPSF, Forecaster, FState, Interval

DEFAULT_BURN_IN = 60     # skip the warm-up transient of the backtest


class ConformalBand(NamedTuple):
    q: jax.Array         # f32 residual quantile = interval half-width
    alpha: float         # nominal coverage level
    scale: jax.Array     # f32 mean |y| of the calibration split


def _residuals(forecaster: Forecaster, y: jax.Array,
               burn_in: int) -> jax.Array:
    y2 = jnp.asarray(y, jnp.float32)
    if y2.ndim == 1:
        y2 = y2[None, :]
    preds = forecaster.smooth(y2)
    return jnp.abs(y2 - preds)[:, burn_in:].reshape(-1)


def calibrate(forecaster: Forecaster, y_calib: jax.Array, *,
              alpha: float = 0.9,
              burn_in: int = DEFAULT_BURN_IN) -> ConformalBand:
    """Fit a band on a calibration split. y_calib [T] or [B, T]."""
    resid = _residuals(forecaster, y_calib, burn_in)
    n = resid.shape[0]
    if n < 1:
        raise ValueError("calibration split shorter than burn_in")
    # split-conformal rank: the ceil((n+1)*alpha)-th order statistic
    k = min(int(math.ceil((n + 1) * alpha)), n)
    q = jnp.sort(resid)[k - 1]
    scale = jnp.mean(jnp.abs(jnp.asarray(y_calib, jnp.float32)))
    return ConformalBand(q=q, alpha=float(alpha), scale=scale)


def coverage(forecaster: Forecaster, band: ConformalBand,
             y_test: jax.Array, *,
             burn_in: int = DEFAULT_BURN_IN) -> float:
    """Empirical rate at which |y - pred| <= q on a held-out split."""
    resid = _residuals(forecaster, y_test, burn_in)
    return float(jnp.mean(resid <= band.q))


def wrap(forecaster: Forecaster, band: ConformalBand, *,
         widen_with_horizon: bool = True) -> Forecaster:
    """Forecaster whose intervals carry the conformal band instead of the
    native residual-EWMA one. The band is calibrated at horizon 1; longer
    horizons widen by sqrt(h) (random-walk error growth) unless disabled."""

    def forecast(state: FState, horizon: int) -> Interval:
        point = forecaster.forecast(state, horizon).point
        half = band.q * (jnp.sqrt(jnp.float32(horizon))
                         if widen_with_horizon else 1.0)
        return Interval(point=point,
                        lo=jnp.maximum(point - half, 0.0),
                        hi=point + half)

    return Forecaster(f"conformal[{forecaster.name}]", forecaster.init,
                      forecaster.update, forecast, forecaster.smooth)


def confidence(band: ConformalBand) -> jax.Array:
    """Scalar confidence of a calibrated band: 1 for a zero-width band,
    monotonically decreasing in the band's width relative to the trace
    scale — the signal Algorithm 1 consumes."""
    width = 2.0 * band.q
    return band.scale / jnp.maximum(band.scale + width, EPSF)
