"""Batched offline backtests: forecasters x series in ONE jitted scan.

Conformal calibration and the forecast benchmarks replay every candidate
forecaster over every trace. Running `forecaster.smooth` per model costs
one compile and one dispatch per forecaster; here the models' states ride
in one scan carry (every-lane-evaluates-all-F — fine for forecasters,
whose updates are a handful of FLOPs; the heterogeneous-controller batch
in ``repro.scaling.batch`` outgrew the same design because `decide`s are
not), so the whole F x B x T backtest is one compile and one dispatch.
Lane f's predictions are exactly the streaming path of forecaster f
alone (`stream_smooth`, pinned by test).
"""
from __future__ import annotations

from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.forecast import registry
from repro.forecast.api import Forecaster


def _resolve(forecasters: Sequence[Forecaster | str]) -> list[Forecaster]:
    return [registry.make(f) for f in forecasters]


def stream_smooth(forecaster: Forecaster | str, y: jax.Array) -> jax.Array:
    """Streaming one-step backtest of one forecaster: scan of
    forecast(·, 1) + update. y [B, T] -> preds [B, T].

    This is the per-forecaster reference path for `batch_smooth` (and is
    identical to `forecaster.smooth` for models without a custom offline
    kernel path)."""
    f = registry.make(forecaster)

    def one(series):
        def body(st, yt):
            return f.update(st, yt), f.forecast(st, 1).point
        _, preds = jax.lax.scan(body, f.init(), series)
        return preds

    return jax.vmap(one)(jnp.asarray(y, jnp.float32))


def make_batch_backtest(forecasters: Sequence[Forecaster | str]):
    """jitted fn: y [B, T] -> one-step-ahead predictions [F, B, T]."""
    fcs = _resolve(forecasters)

    def run(y):
        def one_series(series):
            def body(states, yt):
                preds = jnp.stack([f.forecast(s, 1).point
                                   for f, s in zip(fcs, states)])
                new = tuple(f.update(s, yt) for f, s in zip(fcs, states))
                return new, preds
            init = tuple(f.init() for f in fcs)
            _, out = jax.lax.scan(body, init, series)     # [T, F]
            return out.T                                  # [F, T]

        return jax.vmap(one_series, in_axes=0,
                        out_axes=1)(jnp.asarray(y, jnp.float32))

    return jax.jit(run)


def batch_smooth(forecasters: Sequence[Forecaster | str],
                 y: jax.Array, *, b_chunk: int | None = None) -> jax.Array:
    """Convenience wrapper: y [B, T] -> predictions [F, B, T].

    `b_chunk` runs the backtest `b_chunk` series at a time (one compile,
    reused per chunk; the tail chunk is zero-padded to the chunk shape
    and trimmed) so fleet-sized B never materializes an [F, B, T] device
    intermediate — each series' lane is independent, so the chunked
    predictions are bit-identical to the unchunked ones."""
    B = int(np.shape(y)[0])
    if b_chunk is None or b_chunk >= B:
        return make_batch_backtest(forecasters)(y)
    if b_chunk <= 0:
        raise ValueError(f"b_chunk must be positive, got {b_chunk}")
    fn = make_batch_backtest(forecasters)
    y = np.asarray(y, np.float32)
    outs = []
    for lo in range(0, B, b_chunk):
        chunk = y[lo:lo + b_chunk]
        n = chunk.shape[0]
        if n < b_chunk:          # pad the tail so the compile is reused
            chunk = np.concatenate(
                [chunk, np.zeros((b_chunk - n,) + chunk.shape[1:],
                                 np.float32)])
        outs.append(np.asarray(fn(chunk))[:, :n])
    return jnp.concatenate([jnp.asarray(o) for o in outs], axis=1)
