"""The built-in forecasters: Holt-Winters, linear trend, seasonal naive,
and EWMA — one factory per model, all assembled through
``api.make_forecaster`` so residual tracking, native intervals, and the
scan-based backtest come for free.

Holt-Winters is the only one with a custom offline path: `smooth`
dispatches to the Pallas TPU kernel (``repro.kernels.holt_winters``) when
a TPU backend is attached and falls back to the pure-jnp oracle
(``repro.core.forecasting.hw_smooth``, the same function ``kernels/ref``
wraps) on CPU, where interpret-mode Pallas would be orders of magnitude
slower.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import forecasting as fc
from repro.forecast.api import Forecaster, make_forecaster


# ---------------------------------------------------------- Holt-Winters ----
def holt_winters_forecaster(*, period: int = 60, alpha: float = 0.1,
                            beta: float = 0.01,
                            gamma: float = 0.3) -> Forecaster:
    """Additive-seasonal triple exponential smoothing (PERIODIC strategy,
    paper Table III; the Generic-Predictive baseline, §IV.C)."""

    def smooth_fn(y):
        y = jnp.asarray(y, jnp.float32)
        flat = y.reshape((-1, y.shape[-1]))
        if jax.default_backend() == "tpu":
            from repro.kernels import ops
            out = ops.holt_winters(flat, period=period, alpha=alpha,
                                   beta=beta, gamma=gamma, interpret=False)
        else:
            out = fc.hw_smooth(flat, period=period, alpha=alpha,
                               beta=beta, gamma=gamma)
        return out.reshape(y.shape)

    return make_forecaster(
        "holt_winters",
        init_inner=lambda: fc.hw_init(period),
        update_inner=lambda st, y: fc.hw_step(st, y, alpha=alpha,
                                              beta=beta, gamma=gamma),
        point_fn=lambda st, h: jnp.maximum(fc.hw_forecast_max(st, h), 0.0),
        smooth_fn=smooth_fn)


# ----------------------------------------------------------- linear trend ----
def linear_trend_forecaster(*, window: int = 30) -> Forecaster:
    """OLS trend extrapolation over a sliding window (RAMP strategy).
    State is just the [window] ring of most recent observations."""

    def point(buf: jax.Array, h: int):
        p1 = fc.linear_trend_forecast(buf, 1)
        ph = fc.linear_trend_forecast(buf, h)
        # peak over the horizon: a line attains its max at an endpoint
        return jnp.maximum(p1, ph)

    return make_forecaster(
        "linear_trend",
        init_inner=lambda: jnp.zeros((window,), jnp.float32),
        update_inner=lambda buf, y: jnp.concatenate([buf[1:], y[None]]),
        point_fn=point)


# --------------------------------------------------------- seasonal naive ----
class SeasonalState(NamedTuple):
    season: jax.Array    # [period] last observation at each phase
    t: jax.Array         # int32 samples seen


def seasonal_naive_forecaster(*, period: int = 60) -> Forecaster:
    """Repeat the value one period ago (the classic strong baseline for
    cyclic serverless traffic; needs one full period of warm-up)."""

    def update(st: SeasonalState, y):
        return SeasonalState(season=st.season.at[st.t % period].set(y),
                             t=st.t + 1)

    def point(st: SeasonalState, h: int):
        phases = (st.t + jnp.arange(1, h + 1) - 1) % period
        return jnp.maximum(jnp.max(st.season[phases]), 0.0)

    return make_forecaster(
        "seasonal_naive",
        init_inner=lambda: SeasonalState(
            season=jnp.zeros((period,), jnp.float32), t=jnp.int32(0)),
        update_inner=update,
        point_fn=point)


# ------------------------------------------------------------------- EWMA ----
def ewma_forecaster(*, alpha: float = 0.3) -> Forecaster:
    """Exponentially weighted level; flat forecast at every horizon (the
    conservative choice for SPIKE / STATIONARY_NOISY archetypes)."""
    return make_forecaster(
        "ewma",
        init_inner=lambda: jnp.float32(0.0),
        update_inner=lambda lvl, y: lvl + alpha * (y - lvl),
        point_fn=lambda lvl, h: jnp.maximum(lvl, 0.0))
