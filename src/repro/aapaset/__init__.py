"""AAPAset: the paper's 300K weakly labeled window dataset as a scalable
engine — chunked jitted build, content-addressed shard cache, named
dataset registry, and sharded loaders (paper §III.B, §IV.A).

    from repro import aapaset
    loader = aapaset.AAPAsetLoader.from_name("aapaset_ci")
    X, y, conf = loader.arrays("train")
"""
from repro.aapaset.build import BuiltDataset, featurize_windows
from repro.aapaset.loader import AAPAsetLoader
from repro.aapaset.manifest import (DEFAULT_ROOT, DatasetConfig,
                                    build_or_load, config_hash,
                                    dataset_card, is_cached, read_manifest)
from repro.aapaset.registry import available, get, register

__all__ = [
    "AAPAsetLoader", "BuiltDataset", "DatasetConfig", "DEFAULT_ROOT",
    "available", "build_or_load", "config_hash", "dataset_card",
    "featurize_windows", "get", "is_cached", "read_manifest", "register",
]
