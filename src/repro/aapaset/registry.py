"""Named AAPAset configs.

* ``aapaset_300k`` — the paper-scale artifact (§IV.A: ~300K weakly
  labeled Azure-Functions windows; 150 functions x 14 days x 60-min
  windows at 10-min stride ~= 300K). `slow` tier: nightly CI builds it.
* ``aapaset_ci`` — ~10K windows, builds in seconds on CPU; tier-1 CI and
  the examples train from it.
* ``spike_heavy`` / ``regime_switch`` / ``diurnal_burst`` — scenario-
  diversity variants backed by the trace families in
  ``repro.data.azure_synth.FAMILY_SPECS``.

``get(name, **overrides)`` returns a frozen config; content-field
overrides flow into the content hash, so a tweaked variant never
collides with the named artifact it was derived from. The two execution
knobs (`chunk`, `shard_rows`) are the deliberate exception: they cannot
change dataset bytes, so overriding only them resolves to the same
address — an existing cached artifact is served as-is (its shard layout
reflects whatever knobs built it).
"""
from __future__ import annotations

import dataclasses

from repro.aapaset.manifest import DatasetConfig

_REGISTRY: dict[str, DatasetConfig] = {}


def register(cfg: DatasetConfig) -> DatasetConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"dataset {cfg.name!r} already registered")
    _REGISTRY[cfg.name] = cfg
    return cfg


def available() -> list[str]:
    return sorted(_REGISTRY)


def get(name: str, **overrides) -> DatasetConfig:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown dataset {name!r}; available: {available()}")
    cfg = _REGISTRY[name]
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


register(DatasetConfig("aapaset_300k", n_functions=150, n_days=14, seed=0))
register(DatasetConfig("aapaset_ci", n_functions=18, n_days=4, seed=0))
register(DatasetConfig("spike_heavy", n_functions=96, n_days=7, seed=1,
                       family="spike_heavy"))
register(DatasetConfig("regime_switch", n_functions=96, n_days=7, seed=2,
                       family="regime_switch"))
register(DatasetConfig("diurnal_burst", n_functions=96, n_days=7, seed=3,
                       family="diurnal_burst"))
