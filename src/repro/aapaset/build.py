"""Chunked, jitted AAPAset builder: traces -> windows -> 38 features ->
10-LF weak labels + agreement confidence -> day splits (paper §III.B).

The seed-state path (`core.pipeline.featurize_and_label`) ran a host
list-append loop with a fresh dispatch per variable-size batch. Here the
whole per-window computation — feature extraction (Pallas
``window_features_kernel`` when a TPU backend is attached, the pure-jnp
``kernels.ref`` oracle math on CPU) plus LF voting and majority
aggregation — is ONE jitted fixed-chunk-size step. Every chunk of every
dataset reuses the same compilation (the
last chunk is zero-padded to the chunk shape; compile-cache growth is
pinned by test), and the window buffer is sharded over the
``repro.dist.sharding`` "dp" axis when a mesh is active (no-op without).

The output is bit-exact with the legacy host-loop path (pinned by test):
all math is per-window, so chunking and padding cannot change any valid
row.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import features as F
from repro.core import labeling
from repro.data import windows as W
from repro.data.azure_synth import generate_traces
from repro.dist import sharding as shd

DEFAULT_CHUNK = 8192
SPLIT_NAMES = ("train", "val", "test")


@dataclasses.dataclass
class BuiltDataset:
    """Materialized AAPAset: window tensors + weak labels + provenance.

    `split` codes rows 0/1/2 = train/val/test (``SPLIT_NAMES``); `votes`
    keeps the raw per-LF outputs so dataset cards can report coverage and
    conflict without re-running the LFs.
    """

    windows: np.ndarray      # [N, W] f32 per-minute invocation counts
    features: np.ndarray     # [N, 38] f32
    labels: np.ndarray       # [N] int32 in {-1, 0..3} (-1 = all abstained)
    confidence: np.ndarray   # [N] f32 LF agreement fraction
    votes: np.ndarray        # [N, N_LFS] int8 raw LF outputs
    func_id: np.ndarray      # [N] int32
    start_min: np.ndarray    # [N] int32
    pattern: np.ndarray      # [N] int32 generator ground truth
    day: np.ndarray          # [N] int32 1-based day of window end
    split: np.ndarray        # [N] int8 0/1/2 = train/val/test
    series: np.ndarray       # [F_active, T] f32 counts of kept functions
    series_pattern: np.ndarray  # [F_active] int32

    def __len__(self):
        return self.windows.shape[0]

    def split_mask(self, name: str) -> np.ndarray:
        return self.split == SPLIT_NAMES.index(name)


@partial(jax.jit, static_argnames=("use_kernel",))
def _build_chunk(wb: jax.Array, *, use_kernel: bool):
    """One fixed-shape chunk step: windows [C, W] -> (features [C, 38],
    labels [C], confidence [C], votes [C, N_LFS])."""
    wb = shd.constrain(wb, ("dp", None))
    if use_kernel:
        from repro.kernels import ops
        feats = ops.extract_features_fused(wb, interpret=False)
    else:
        feats = F.extract_features(wb)
    # keep the LF stage from fusing into (and renumbering) the feature
    # stage: features must stay bit-exact with the standalone
    # extract_features path
    feats = jax.lax.optimization_barrier(feats)
    votes = labeling.apply_lfs(feats)
    labels, conf, _ = labeling.majority_vote(votes)
    return feats, labels, conf, votes.astype(jnp.int8)


def featurize_windows(windows: np.ndarray, *, chunk: int = DEFAULT_CHUNK,
                      use_kernel: bool | None = None):
    """Extract 38 features + weak labels + LF votes for every window.

    Returns (features [N, 38], labels [N], confidence [N],
    votes [N, N_LFS]) as host arrays. One compilation per (chunk, W)
    shape regardless of dataset size.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    windows = np.asarray(windows, np.float32)
    N, width = windows.shape

    feats = np.empty((N, F.N_FEATURES), np.float32)
    labels = np.empty((N,), np.int32)
    conf = np.empty((N,), np.float32)
    votes = np.empty((N, labeling.N_LFS), np.int8)
    for lo in range(0, N, chunk):
        hi = min(lo + chunk, N)
        wb = windows[lo:hi]
        if hi - lo < chunk:               # zero-pad the tail chunk
            wb = np.concatenate(
                [wb, np.zeros((chunk - (hi - lo), width), np.float32)])
        fb, lb, cb, vb = _build_chunk(jnp.asarray(wb),
                                      use_kernel=use_kernel)
        n = hi - lo
        feats[lo:hi] = np.asarray(fb)[:n]
        labels[lo:hi] = np.asarray(lb)[:n]
        conf[lo:hi] = np.asarray(cb)[:n]
        votes[lo:hi] = np.asarray(vb)[:n]
    return feats, labels, conf, votes


def build(cfg) -> BuiltDataset:
    """Full build for one `manifest.DatasetConfig`: generate traces, slice
    windows, run the chunked featurize/label step, assign day splits."""
    traces = generate_traces(n_functions=cfg.n_functions,
                             n_days=cfg.n_days, seed=cfg.seed,
                             family=cfg.family)
    ds = W.make_windows(traces, window=cfg.window, stride=cfg.stride,
                        min_total_invocations=cfg.min_total_invocations)
    feats, labels, conf, votes = featurize_windows(
        ds.windows, chunk=cfg.chunk,
        use_kernel=cfg.resolved_feature_path() == "kernel")

    masks = W.default_day_split(ds, cfg.n_days)
    split = np.full((len(ds),), -1, np.int8)
    for code, name in enumerate(SPLIT_NAMES):
        split[masks[name]] = code
    if (split < 0).any():
        raise AssertionError("day split left windows unassigned — "
                             "default_day_split must cover every day")

    active = np.unique(ds.func_id)
    return BuiltDataset(
        windows=ds.windows, features=feats, labels=labels,
        confidence=conf, votes=votes, func_id=ds.func_id,
        start_min=ds.start_min, pattern=ds.pattern,
        day=ds.day().astype(np.int32), split=split,
        series=traces.counts[active].astype(np.float32),
        series_pattern=traces.pattern[active].astype(np.int32))
