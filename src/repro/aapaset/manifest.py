"""Content-addressed AAPAset artifacts: npz shards + a JSON manifest.

An artifact is addressed by the sha256 of its *content key* — the
(config, seed) fields that determine every byte of the dataset under the
current code, excluding execution knobs (chunk size, rows per shard)
that are bit-exactness-invariant. Rebuilding the same config is a cache
hit; every benchmark and test can name the exact dataset it ran on by
``name-hash12``. The address does NOT fingerprint the producing code:
any change to the trace generators, feature math, or labeling functions
that alters dataset bytes MUST bump ``SCHEMA_VERSION`` so cached
artifacts (local trees and the CI actions/cache) invalidate.

The manifest carries a dataset card (class balance, LF coverage/conflict,
agreement, split sizes, archetypes present) plus per-shard row counts and
sha256 digests of the raw array bytes (array digests, not npz file bytes,
so the address is independent of zip timestamps).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import shutil
import time

import numpy as np

from repro.core.archetypes import ARCHETYPE_NAMES
from repro.core.labeling import LABELING_FUNCTIONS
from repro.aapaset.build import (DEFAULT_CHUNK, SPLIT_NAMES, BuiltDataset,
                                 build)

SCHEMA_VERSION = 1
DEFAULT_ROOT = pathlib.Path("experiments/aapaset")

_SHARD_KEYS = ("windows", "features", "labels", "confidence", "votes",
               "func_id", "start_min", "pattern", "day", "split")


@dataclasses.dataclass(frozen=True)
class DatasetConfig:
    """One named AAPAset build. Content fields address the artifact;
    `chunk` and `shard_rows` are execution knobs (excluded from the hash —
    they cannot change any output byte).

    `feature_path` selects the feature implementation: "ref" (pure-jnp
    oracle math, bit-exact everywhere), "kernel" (the Pallas TPU kernel,
    ~5e-4-close to ref), or "auto" (kernel iff a TPU backend is
    attached). The RESOLVED value is part of the content key, because
    kernel- and ref-built artifacts differ in low-order bits — the same
    address must never map to different bytes."""

    name: str
    n_functions: int
    n_days: int
    seed: int = 0
    family: str = "default"
    window: int = 60
    stride: int = 10
    min_total_invocations: float = 1000.0
    feature_path: str = "auto"      # "auto" | "kernel" | "ref"
    chunk: int = DEFAULT_CHUNK
    shard_rows: int = 65536

    def resolved_feature_path(self) -> str:
        if self.feature_path != "auto":
            return self.feature_path
        import jax
        return "kernel" if jax.default_backend() == "tpu" else "ref"

    def content_key(self) -> dict:
        return {"schema": SCHEMA_VERSION, "name": self.name,
                "n_functions": self.n_functions, "n_days": self.n_days,
                "seed": self.seed, "family": self.family,
                "window": self.window, "stride": self.stride,
                "min_total_invocations": self.min_total_invocations,
                "feature_path": self.resolved_feature_path()}


def hash_json(obj, n: int = 12) -> str:
    """The one content-keying recipe: sha256 of canonical JSON."""
    blob = json.dumps(obj, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:n]


def sweep_stale_tmp(parent: pathlib.Path, pattern: str,
                    max_age_s: float = 3600.0) -> None:
    """Remove `.tmp-*` staging files/dirs orphaned by killed writers.

    The age gate spares LIVE concurrent writers: their staging paths are
    written within seconds of creation, orphans sit for hours."""
    cutoff = time.time() - max_age_s
    for stale in parent.glob(pattern):
        try:
            if stale.stat().st_mtime >= cutoff:
                continue
            if stale.is_dir():
                shutil.rmtree(stale, ignore_errors=True)
            else:
                stale.unlink()
        except OSError:
            pass


def stage_dir(out: pathlib.Path) -> pathlib.Path:
    """Per-process staging directory next to `out`, with stale-orphan
    sweep. Pair with `publish_dir`."""
    out.parent.mkdir(parents=True, exist_ok=True)
    sweep_stale_tmp(out.parent, f".tmp-{out.name}-*")
    tmp = out.parent / f".tmp-{out.name}-{os.getpid()}"
    tmp.mkdir(parents=True, exist_ok=True)
    return tmp


def publish_dir(tmp: pathlib.Path, out: pathlib.Path,
                sentinel: str) -> None:
    """Atomic rename with same-address race semantics: if a concurrent
    writer published first (`sentinel` exists under `out`), drop our copy
    — both built identical bytes. A stale partial dir (pre-atomic crash,
    no sentinel) is cleared and replaced; if a concurrent repairer wins
    that retry, adopt its copy and drop ours."""
    try:
        tmp.replace(out)
    except OSError:
        if (out / sentinel).exists():
            shutil.rmtree(tmp, ignore_errors=True)
        else:
            shutil.rmtree(out, ignore_errors=True)
            try:
                tmp.replace(out)
            except OSError:
                shutil.rmtree(tmp, ignore_errors=True)


def config_hash(cfg: DatasetConfig) -> str:
    return hash_json(cfg.content_key())


def artifact_dir(cfg: DatasetConfig,
                 root: pathlib.Path | str = DEFAULT_ROOT) -> pathlib.Path:
    return pathlib.Path(root) / f"{cfg.name}-{config_hash(cfg)}"


def is_cached(cfg: DatasetConfig,
              root: pathlib.Path | str = DEFAULT_ROOT) -> bool:
    return (artifact_dir(cfg, root) / "manifest.json").exists()


def _digest(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes())
    return h.hexdigest()


def dataset_card(built: BuiltDataset) -> dict:
    """Class balance, LF coverage/conflict, agreement, split sizes."""
    y, votes = built.labels, built.votes
    labeled = y >= 0
    n_labeled = int(labeled.sum())
    balance = np.bincount(y[labeled], minlength=4) / max(n_labeled, 1)

    fired = votes >= 0
    coverage = fired.mean(axis=0)
    # conflict: >= 2 LFs fired and disagree (vectorized over all windows)
    vmax = np.where(fired, votes, -1).max(axis=1)
    vmin = np.where(fired, votes, 127).min(axis=1)
    multi = fired.sum(axis=1) >= 2
    conflict = float((multi & (vmax != vmin)).mean())

    return {
        "n_windows": len(built),
        "n_labeled": n_labeled,
        "abstain_rate": float((~labeled).mean()),
        "class_balance": {n: float(b) for n, b in
                          zip(ARCHETYPE_NAMES, balance)},
        "archetypes_present": [n for n, b in
                               zip(ARCHETYPE_NAMES, balance) if b > 0],
        "lf_coverage": {fn.__name__: float(c) for fn, c in
                        zip(LABELING_FUNCTIONS, coverage)},
        "lf_conflict_rate": conflict,
        "mean_agreement": float(built.confidence[labeled].mean())
        if n_labeled else 0.0,
        "split_sizes": {name: int((built.split == code).sum())
                        for code, name in enumerate(SPLIT_NAMES)},
        "n_functions_kept": int(built.series.shape[0]),
    }


def save(built: BuiltDataset, cfg: DatasetConfig,
         root: pathlib.Path | str = DEFAULT_ROOT) -> dict:
    """Write npz shards + series + manifest.json; returns the manifest.

    Everything is staged into a per-process temp directory and published
    with one atomic rename, so neither a crash mid-save nor a concurrent
    builder of the same address can expose a half-written artifact (the
    rename loser discards its copy — both built identical bytes).
    """
    out = artifact_dir(cfg, root)
    tmp = stage_dir(out)

    shards = []
    for i, lo in enumerate(range(0, max(len(built), 1), cfg.shard_rows)):
        hi = min(lo + cfg.shard_rows, len(built))
        arrays = {k: getattr(built, k)[lo:hi] for k in _SHARD_KEYS}
        np.savez_compressed(tmp / f"shard-{i:05d}.npz", **arrays)
        shards.append({"file": f"shard-{i:05d}.npz", "rows": hi - lo,
                       "sha256": _digest(arrays)})

    series = {"series": built.series,
              "series_pattern": built.series_pattern}
    np.savez_compressed(tmp / "series.npz", **series)

    manifest = {
        "schema": SCHEMA_VERSION,
        "config": dataclasses.asdict(cfg),
        "hash": config_hash(cfg),
        "card": dataset_card(built),
        "shards": shards,
        "series_sha256": _digest(series),
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)

    publish_dir(tmp, out, "manifest.json")
    return manifest


def read_manifest(cfg: DatasetConfig,
                  root: pathlib.Path | str = DEFAULT_ROOT) -> dict:
    with open(artifact_dir(cfg, root) / "manifest.json") as f:
        return json.load(f)


def load(cfg: DatasetConfig, root: pathlib.Path | str = DEFAULT_ROOT,
         *, verify: bool = False,
         manifest: dict | None = None) -> BuiltDataset:
    """Reassemble a BuiltDataset from its shards (cache hit)."""
    out = artifact_dir(cfg, root)
    if manifest is None:
        manifest = read_manifest(cfg, root)
    parts: dict[str, list] = {k: [] for k in _SHARD_KEYS}
    for sh in manifest["shards"]:
        with np.load(out / sh["file"]) as z:
            arrays = {k: z[k] for k in _SHARD_KEYS}
        if verify and _digest(arrays) != sh["sha256"]:
            raise ValueError(f"corrupt shard {sh['file']} in {out}")
        for k in _SHARD_KEYS:
            parts[k].append(arrays[k])
    with np.load(out / "series.npz") as z:
        series = z["series"]
        series_pattern = z["series_pattern"]
    return BuiltDataset(
        **{k: np.concatenate(parts[k]) for k in _SHARD_KEYS},
        series=series, series_pattern=series_pattern)


def build_or_load(cfg: DatasetConfig,
                  root: pathlib.Path | str = DEFAULT_ROOT,
                  *, verify: bool = False) -> tuple[BuiltDataset, dict]:
    """The engine's front door: content-addressed build with caching."""
    if is_cached(cfg, root):
        manifest = read_manifest(cfg, root)
        return load(cfg, root, verify=verify,
                    manifest=manifest), manifest
    built = build(cfg)
    return built, save(built, cfg, root)
