"""Deterministic sharded loaders over built AAPAset artifacts.

One loader feeds all three consumers:

* ``arrays(split)`` — full-split (X, y, conf) host arrays for
  ``core.gbdt.fit`` and ``core.calibration.fit`` (both are full-batch);
* ``batches(split, ...)`` — seeded, shardable minibatch iterator
  (``shard_index``/``num_shards`` partition the permutation the way a
  ``repro.dist.sharding`` dp axis would split a global batch);
* ``series()`` — the kept functions' count series for
  ``forecast.backtest`` / ``forecast.conformal``.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from repro.aapaset import manifest as MF
from repro.aapaset import registry
from repro.aapaset.build import BuiltDataset


@dataclasses.dataclass
class AAPAsetLoader:
    data: BuiltDataset
    manifest: dict

    @classmethod
    def from_name(cls, name: str,
                  root: pathlib.Path | str = MF.DEFAULT_ROOT,
                  **overrides) -> "AAPAsetLoader":
        """Build-or-load a registry dataset and wrap it."""
        cfg = registry.get(name, **overrides)
        built, man = MF.build_or_load(cfg, root)
        return cls(built, man)

    @property
    def name(self) -> str:
        return self.manifest["config"]["name"]

    @property
    def dataset_id(self) -> str:
        """`name-hash12`: the exact artifact identity for logs/benches."""
        return f"{self.name}-{self.manifest['hash']}"

    def split_indices(self, split: str | None = None,
                      *, labeled_only: bool = True) -> np.ndarray:
        mask = np.ones(len(self.data), bool) if split is None \
            else self.data.split_mask(split)
        if labeled_only:
            mask = mask & (self.data.labels >= 0)
        return np.nonzero(mask)[0]

    def arrays(self, split: str | None = None,
               *, labeled_only: bool = True):
        """(X [n, 38], y [n], conf [n]) host arrays for one split."""
        idx = self.split_indices(split, labeled_only=labeled_only)
        return (self.data.features[idx], self.data.labels[idx],
                self.data.confidence[idx])

    def batches(self, split: str, batch_size: int, *, seed: int = 0,
                shard_index: int = 0, num_shards: int = 1,
                labeled_only: bool = True,
                drop_remainder: bool = True) -> Iterator[tuple]:
        """Deterministic minibatches of (X, y, conf) as jnp arrays.

        The same (seed, num_shards) always yields the same batch stream;
        shards partition the shuffled index set disjointly. With
        ``drop_remainder=True`` (the lockstep data-parallel setting)
        every shard sees the same number of rows and batches; with
        ``False`` the shards cover the split exactly.
        """
        if not 0 <= shard_index < num_shards:
            raise ValueError(f"shard_index {shard_index} out of range "
                             f"for num_shards {num_shards}")
        idx = self.split_indices(split, labeled_only=labeled_only)
        perm = np.random.default_rng(seed).permutation(idx)
        mine = perm[shard_index::num_shards]
        if drop_remainder:            # equalize shards for lockstep dp
            mine = mine[: len(perm) // num_shards]
        stop = len(mine) - (len(mine) % batch_size if drop_remainder
                            else 0)
        for lo in range(0, stop, batch_size):
            take = mine[lo:lo + batch_size]
            yield (jnp.asarray(self.data.features[take]),
                   jnp.asarray(self.data.labels[take]),
                   jnp.asarray(self.data.confidence[take]))

    def series(self, *, max_functions: int | None = None) -> np.ndarray:
        """[F, T] counts of the kept functions, for forecast backtests."""
        s = self.data.series
        return s if max_functions is None else s[:max_functions]

    def rate_chunks(self, n_workloads: int, w_chunk: int, *,
                    minutes: int | None = None, seed: int = 0,
                    shard_index: int = 0,
                    num_shards: int = 1) -> Iterator[np.ndarray]:
        """Deterministic fleet feed: [w_chunk, minutes] trace chunks for
        ``repro.evals.fleet`` streaming runs, sampled (with replacement
        past F) from the kept functions' count series.

        Chunk c is drawn with rng seeded on (seed, c), so any chunk can
        be regenerated independently of the others, and a fleet larger
        than the artifact never materializes [W, T] on one host — each
        shard generates only the chunks where ``c % num_shards ==
        shard_index`` (disjoint, jointly exhaustive), the way a
        multi-host launcher would split the fleet."""
        if n_workloads % w_chunk:
            raise ValueError(f"w_chunk {w_chunk} must divide "
                             f"n_workloads {n_workloads}")
        if not 0 <= shard_index < num_shards:
            raise ValueError(f"shard_index {shard_index} out of range "
                             f"for num_shards {num_shards}")
        s = self.data.series
        T = s.shape[1] if minutes is None else min(int(minutes), s.shape[1])
        for c in range(n_workloads // w_chunk):
            if c % num_shards != shard_index:
                continue
            rng = np.random.default_rng(
                np.random.SeedSequence([seed, c]))
            take = rng.integers(0, s.shape[0], size=w_chunk)
            yield np.ascontiguousarray(s[take, :T]).astype(np.float32)
