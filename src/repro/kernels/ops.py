"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode for validation;
on TPU set ``interpret=False`` (the default flips automatically based on
the backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.features import freq_features
from repro.kernels.episode_block import episode_minutes
from repro.kernels.gbdt_tables import gbdt_logits_kernel
from repro.kernels.holt_winters import holt_winters_kernel
from repro.kernels.plant_block import plant_block_kernel
from repro.kernels.window_features import window_features_kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def window_features(windows: jax.Array, *, tile_n: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """[N, W] -> 28 stat/time features [N, 28] via the fused kernel."""
    if interpret is None:
        interpret = _default_interpret()
    return window_features_kernel(windows, tile_n=tile_n,
                                  interpret=interpret)


def extract_features_fused(windows: jax.Array, *, tile_n: int = 256,
                           interpret: bool | None = None) -> jax.Array:
    """All 38 AAPA features: fused Pallas kernel (28) + XLA rFFT (10)."""
    st = window_features(windows, tile_n=tile_n, interpret=interpret)
    fq = freq_features(windows)
    return jnp.concatenate([st, fq], axis=-1)


def holt_winters(y: jax.Array, *, period: int = 60, alpha: float = 0.1,
                 beta: float = 0.01, gamma: float = 0.3, tile_b: int = 8,
                 interpret: bool | None = None) -> jax.Array:
    """[B, T] -> one-step-ahead Holt-Winters forecasts [B, T]."""
    if interpret is None:
        interpret = _default_interpret()
    return holt_winters_kernel(y, period=period, alpha=alpha, beta=beta,
                               gamma=gamma, tile_b=tile_b,
                               interpret=interpret)


def plant_tick_block(ready, pipeline, queue, wait_sum, util_ema, cooldown,
                     pipe_sum, arrivals, *, n_ticks: int,
                     rps_per_replica: float = 20.0,
                     service_sec: float = 0.1, slo_sec: float = 0.5,
                     resp_cap_sec: float = 600.0,
                     metric_tau_sec: float = 60.0, tile_b: int = 8,
                     interpret: bool | None = None):
    """Advance [B] cluster-plant lanes a whole control period (`n_ticks`
    seconds, no decisions) via the fused kernel. Contract of
    ``repro.sim.cluster.plant_block_ref``: (state tuple, [B, T] ticks)."""
    if interpret is None:
        interpret = _default_interpret()
    return plant_block_kernel(
        ready, pipeline, queue, wait_sum, util_ema, cooldown, pipe_sum,
        arrivals, n_ticks=n_ticks, rps_per_replica=rps_per_replica,
        service_sec=service_sec, slo_sec=slo_sec,
        resp_cap_sec=resp_cap_sec, metric_tau_sec=metric_tau_sec,
        tile_b=tile_b, interpret=interpret)


def episode_block(rates, controller, cfg, *, tile_b: int = 8,
                  interpret: bool | None = None):
    """Whole episodes fused on-chip: rates [B, M] -> MinuteOut of [B, M]
    with plant ticks AND `controller.decide` inside one Pallas kernel
    (``repro.kernels.episode_block``). Oracle: the CPU blocked scan
    ``repro.sim.cluster.simulate`` per lane."""
    if interpret is None:
        interpret = _default_interpret()
    return episode_minutes(controller, cfg, rates, tile_b=tile_b,
                           interpret=interpret)


def gbdt_logits(params, X, *, tile_n: int = 128,
                interpret: bool | None = None):
    """GBDT logits [N, K] from raw features X [N, F] via the node-table
    kernel (``repro.kernels.gbdt_tables``); `params` is a trained
    ``repro.core.gbdt.GBDTParams``. Oracle: ``gbdt.predict_logits``
    (the host path over the same flattened tables)."""
    if interpret is None:
        interpret = _default_interpret()
    t = params.tables
    return gbdt_logits_kernel(X, params.bin_edges, t.feat, t.thresh,
                              t.leaf, params.base, tile_n=tile_n,
                              interpret=interpret)
