"""Pure-jnp oracles for every Pallas kernel in this package.

The kernels implement AAPA's two compute hot-spots (DESIGN.md §2):
window feature extraction (28 stat/time-domain features over hundreds of
thousands of 60-minute windows) and batched Holt-Winters smoothing.
"""
from __future__ import annotations

import jax

from repro.core.features import stat_time_features
from repro.core.forecasting import hw_smooth


def window_features_ref(windows: jax.Array) -> jax.Array:
    """[N, W] -> [N, 28] — identical math to repro.core.features."""
    return stat_time_features(windows)


def holt_winters_ref(y: jax.Array, *, period: int = 60, alpha: float = 0.1,
                     beta: float = 0.01, gamma: float = 0.3) -> jax.Array:
    """[B, T] -> one-step-ahead forecasts [B, T]."""
    return hw_smooth(y, period=period, alpha=alpha, beta=beta, gamma=gamma)


def plant_block_ref(ready, pipeline, queue, wait_sum, util_ema, cooldown,
                    pipe_sum, arrivals, *, n_ticks: int,
                    rps_per_replica: float = 20.0, service_sec: float = 0.1,
                    slo_sec: float = 0.5, resp_cap_sec: float = 600.0,
                    metric_tau_sec: float = 60.0):
    """[B] plant lanes advanced `n_ticks` seconds — identical math to the
    blocked path in ``repro.sim.cluster`` (what the CPU sim runs)."""
    from repro.sim.cluster import SimConfig
    from repro.sim.cluster import plant_block_ref as _ref
    cfg = SimConfig(rps_per_replica=rps_per_replica,
                    service_sec=service_sec, slo_sec=slo_sec,
                    resp_cap_sec=resp_cap_sec,
                    metric_tau_sec=metric_tau_sec)
    return _ref(cfg, ready, pipeline, queue, wait_sum, util_ema, cooldown,
                pipe_sum, arrivals, n_ticks=n_ticks)


def episode_block_ref(rates, controller, cfg):
    """rates [B, M] -> MinuteOut of [B, M]: the CPU blocked scan, one
    lane per workload — the dispatch oracle for the fused-decide episode
    kernel (compiled-program parity is ulp-tight, not bitwise; see the
    episode_block module docstring)."""
    from repro.sim.cluster import simulate
    return jax.vmap(lambda r: simulate(r, controller, cfg,
                                       plant_kernel=False))(rates)


def gbdt_logits_ref(params, X):
    """Host node-table inference — the oracle for the GBDT kernel (the
    kernel runs the identical traversal over the identical layout, so
    parity is bit-exact in interpret mode)."""
    from repro.core.gbdt import predict_logits
    return predict_logits(params, X)
