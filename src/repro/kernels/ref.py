"""Pure-jnp oracles for every Pallas kernel in this package.

The kernels implement AAPA's two compute hot-spots (DESIGN.md §2):
window feature extraction (28 stat/time-domain features over hundreds of
thousands of 60-minute windows) and batched Holt-Winters smoothing.
"""
from __future__ import annotations

import jax

from repro.core.features import stat_time_features
from repro.core.forecasting import hw_smooth


def window_features_ref(windows: jax.Array) -> jax.Array:
    """[N, W] -> [N, 28] — identical math to repro.core.features."""
    return stat_time_features(windows)


def holt_winters_ref(y: jax.Array, *, period: int = 60, alpha: float = 0.1,
                     beta: float = 0.01, gamma: float = 0.3) -> jax.Array:
    """[B, T] -> one-step-ahead forecasts [B, T]."""
    return hw_smooth(y, period=period, alpha=alpha, beta=beta, gamma=gamma)
