"""Pallas TPU kernel: batched Holt-Winters triple exponential smoothing.

The Generic-Predictive baseline and AAPA's PERIODIC strategy backtest
Holt-Winters over every workload series (paper §IV.C). The recurrence is
sequential in time, so the TPU mapping is: one grid step per tile of
``TILE_B`` series held in VMEM sublanes, the time loop inside the kernel
(``lax.fori_loop``), and the seasonal state kept as a ``(TILE_B, period)``
VMEM tile updated with one-hot lane masks (the TPU analogue of the GPU
"one thread per series" layout — here one *sublane* per series, lanes
carry the seasonal vector).

Oracle: ``repro.core.forecasting.hw_smooth`` (see ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(y_ref, o_ref, *, period: int, alpha: float, beta: float,
            gamma: float):
    """y_ref: (TILE_B, T) f32; o_ref: (TILE_B, T) one-step-ahead preds."""
    tile_b, T = y_ref.shape
    season0 = jnp.zeros((tile_b, period), jnp.float32)
    lane_p = jax.lax.broadcasted_iota(jnp.int32, (tile_b, period), 1)

    level0 = y_ref[:, 0][:, None]                 # init: level = y[0]
    trend0 = jnp.zeros((tile_b, 1), jnp.float32)

    def body(t, carry):
        level, trend, season = carry
        phase = jax.lax.rem(t, period)
        onehot = (lane_p == phase)
        s_t = jnp.sum(jnp.where(onehot, season, 0.0), axis=1, keepdims=True)

        pred = level + trend + s_t                # 1-step-ahead forecast
        o_ref[:, pl.dslice(t, 1)] = pred

        yt = y_ref[:, pl.dslice(t, 1)]
        level_new = alpha * (yt - s_t) + (1.0 - alpha) * (level + trend)
        trend_new = beta * (level_new - level) + (1.0 - beta) * trend
        s_new = gamma * (yt - level_new) + (1.0 - gamma) * s_t
        season = jnp.where(onehot, s_new, season)
        return level_new, trend_new, season

    jax.lax.fori_loop(0, T, body, (level0, trend0, season0))


@functools.partial(jax.jit,
                   static_argnames=("period", "alpha", "beta", "gamma",
                                    "tile_b", "interpret"))
def holt_winters_kernel(y: jax.Array, *, period: int = 60,
                        alpha: float = 0.1, beta: float = 0.01,
                        gamma: float = 0.3, tile_b: int = 8,
                        interpret: bool = True) -> jax.Array:
    """y [B, T] -> one-step-ahead forecasts [B, T] (f32).

    Matches ``hw_smooth`` semantics: prediction at t is made from state
    after observing y[:t]; the t=0 prediction is the y[0]-initialized level.
    """
    B, T = y.shape
    n_tiles = max((B + tile_b - 1) // tile_b, 1)
    pad_b = n_tiles * tile_b
    x = jnp.zeros((pad_b, T), jnp.float32)
    x = x.at[:B].set(y.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel, period=period, alpha=alpha, beta=beta,
                          gamma=gamma),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_b, T), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_b, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_b, T), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:B]
