"""Pallas TPU kernel: fused cluster-plant control-period advance.

The control-period-blocked simulator (`repro.sim.cluster`) runs
`controller.decide` once per block and then `n_ticks` of pure plant
dynamics — startup-pipeline pop, fluid queue, response model, utilization
EMA, limiter cooldown decay. Those plant ticks are the hot loop of every
paper table, and they are elementwise over lanes (one lane = one
simulated workload), so the TPU mapping is: one grid step per tile of
``TILE_B`` lanes held in VMEM sublanes, the tick loop inside the kernel
(``lax.fori_loop``), the startup pipeline kept as a ``(TILE_B,
startup_sec)`` VMEM tile shifted one slot per tick — the whole control
period advances without touching HBM.

Oracle: ``repro.sim.cluster.plant_block_ref`` (the same math the CPU
blocked path runs; see ref.py). Parity is property-tested in
tests/test_kernel_properties.py over random lane tiles, startup depths,
and tick counts, including non-multiple-of-tile batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPSF = 1e-9

#: packed lane-state column order (matches `plant_tick_block` args)
STATE_COLS = ("ready", "queue", "wait_sum", "util_ema", "cooldown",
              "pipe_sum", "arrivals")


def _kernel(state_ref, pipe_ref, st_out_ref, pipe_out_ref, served_ref,
            viol_ref, cold_ref, total_ref, resp_ref, util_ref, ready_ref,
            *, n_ticks: int, rps_per_replica: float, service_sec: float,
            slo_sec: float, resp_cap_sec: float, metric_tau_sec: float):
    """state_ref: (TILE_B, 7) packed lane state (STATE_COLS order);
    pipe_ref: (TILE_B, S) startup pipeline; per-tick outputs (TILE_B, T)."""
    tile_b, S = pipe_ref.shape
    arrivals = state_ref[:, 6:7]                     # (TILE_B, 1)

    def body(t, carry):
        ready, pipe, queue, wait, util_ema, cool, ps = carry
        # pods finishing startup: pop slot 0, shift the pipeline
        popped = pipe[:, 0:1]
        ready = ready + popped
        pipe = jnp.concatenate(
            [pipe[:, 1:], jnp.zeros((tile_b, 1), jnp.float32)], axis=1)
        ps = jnp.maximum(ps - popped, 0.0)

        # fluid FIFO queue with queue-age tracking (identical div-form math
        # as cluster._flow_tick — see its FMA-stability note)
        throughput = ready * rps_per_replica
        work = queue + arrivals
        served = jnp.minimum(work, throughput)
        new_queue = work - served
        wait_aged = wait + queue
        mean_age = wait_aged / jnp.maximum(work, EPSF)
        wait = wait_aged * new_queue / jnp.maximum(work, EPSF)
        util = served / jnp.maximum(throughput, EPSF)
        resp = (service_sec / jnp.maximum(1.0 - util, 0.05) + mean_age
                + (0.5 * new_queue) / jnp.maximum(throughput, EPSF))
        resp = jnp.minimum(resp, resp_cap_sec)
        resp = jnp.where(served > 0, resp, 0.0)
        viol = jnp.where(resp > slo_sec, served, 0.0)
        cold = jnp.where(ready < 0.5, arrivals, 0.0)

        # metric EMA + limiter cooldown decay (no decisions in a block)
        util_ema = util_ema + (util - util_ema) / metric_tau_sec
        cool = jnp.maximum(cool - 1.0, 0.0)

        served_ref[:, pl.dslice(t, 1)] = served
        viol_ref[:, pl.dslice(t, 1)] = viol
        cold_ref[:, pl.dslice(t, 1)] = cold
        total_ref[:, pl.dslice(t, 1)] = ready + ps
        resp_ref[:, pl.dslice(t, 1)] = resp
        util_ref[:, pl.dslice(t, 1)] = util
        ready_ref[:, pl.dslice(t, 1)] = ready
        return ready, pipe, new_queue, wait, util_ema, cool, ps

    carry0 = (state_ref[:, 0:1], pipe_ref[:, :], state_ref[:, 1:2],
              state_ref[:, 2:3], state_ref[:, 3:4], state_ref[:, 4:5],
              state_ref[:, 5:6])
    ready, pipe, queue, wait, util_ema, cool, ps = jax.lax.fori_loop(
        0, n_ticks, body, carry0)
    st_out_ref[:, :] = jnp.concatenate(
        [ready, queue, wait, util_ema, cool, ps, arrivals], axis=1)
    pipe_out_ref[:, :] = pipe


@functools.partial(
    jax.jit, static_argnames=("n_ticks", "rps_per_replica", "service_sec",
                              "slo_sec", "resp_cap_sec", "metric_tau_sec",
                              "tile_b", "interpret"))
def plant_block_kernel(ready: jax.Array, pipeline: jax.Array,
                       queue: jax.Array, wait_sum: jax.Array,
                       util_ema: jax.Array, cooldown: jax.Array,
                       pipe_sum: jax.Array, arrivals: jax.Array, *,
                       n_ticks: int, rps_per_replica: float = 20.0,
                       service_sec: float = 0.1, slo_sec: float = 0.5,
                       resp_cap_sec: float = 600.0,
                       metric_tau_sec: float = 60.0, tile_b: int = 8,
                       interpret: bool = True):
    """Advance [B] plant lanes `n_ticks` seconds with no control decisions.

    Same contract as the oracle ``repro.sim.cluster.plant_block_ref``:
    returns ``(state, ticks)`` with `state` = (ready, pipeline, queue,
    wait_sum, util_ema, cooldown, pipe_sum) after the block and `ticks` =
    (served, violated, cold, total_replicas, resp, util, ready) of
    [B, n_ticks].
    """
    B = ready.shape[0]
    S = pipeline.shape[1]
    n_tiles = max((B + tile_b - 1) // tile_b, 1)
    pad_b = n_tiles * tile_b

    state = jnp.zeros((pad_b, 7), jnp.float32)
    cols = (ready, queue, wait_sum, util_ema, cooldown, pipe_sum, arrivals)
    state = state.at[:B].set(
        jnp.stack([c.astype(jnp.float32) for c in cols], axis=1))
    pipe = jnp.zeros((pad_b, S), jnp.float32)
    pipe = pipe.at[:B].set(pipeline.astype(jnp.float32))

    tick_shape = jax.ShapeDtypeStruct((pad_b, n_ticks), jnp.float32)
    row = lambda w: pl.BlockSpec((tile_b, w), lambda i: (i, 0))  # noqa: E731
    st_out, pipe_out, *ticks = pl.pallas_call(
        functools.partial(_kernel, n_ticks=n_ticks,
                          rps_per_replica=rps_per_replica,
                          service_sec=service_sec, slo_sec=slo_sec,
                          resp_cap_sec=resp_cap_sec,
                          metric_tau_sec=metric_tau_sec),
        grid=(n_tiles,),
        in_specs=[row(7), row(S)],
        out_specs=[row(7), row(S)] + [row(n_ticks)] * 7,
        out_shape=[jax.ShapeDtypeStruct((pad_b, 7), jnp.float32),
                   jax.ShapeDtypeStruct((pad_b, S), jnp.float32)]
        + [tick_shape] * 7,
        interpret=interpret,
    )(state, pipe)

    final = (st_out[:B, 0], pipe_out[:B], st_out[:B, 1], st_out[:B, 2],
             st_out[:B, 3], st_out[:B, 4], st_out[:B, 5])
    return final, tuple(t[:B] for t in ticks)
