"""Pallas TPU kernel: vectorized GBDT node-table inference.

The AAPA classifier is a gradient-boosted ensemble whose trees
``repro.core.gbdt`` flattens at fit/load time into contiguous
(feature, threshold, leaf) node tables over one round-major tree axis
(``gbdt.NodeTables``). That layout makes inference a handful of gathered
vector ops — descend every (row, tree) pair one level per step — which
is exactly the shape this kernel executes over a VMEM tile of rows:

* grid step = one ``TILE_N`` tile of rows; X streams in per tile while
  the node tables (tens of KB for the paper-size ensemble) sit in VMEM
  as full blocks shared by every step;
* binning happens in-kernel as a comparison count
  ``sum(edges <= x)`` — integer-identical to the host path's
  ``searchsorted(side="right")`` since both count edges <= value with
  exact float compares;
* the traversal and the per-class logit reduction are literally
  ``gbdt.traverse_tables`` / ``gbdt.table_logits``, so the kernel and
  the host table path cannot drift apart.

Oracle: ``repro.core.gbdt.predict_logits`` (the host table path), which
is itself property-tested bit-close against the retained per-round scan
``predict_logits_scan``. Parity lives in tests/test_kernel_smoke.py
(deterministic tier-1) and tests/test_kernel_properties.py (random
shapes including non-multiple-of-tile row counts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gbdt import NodeTables, table_logits


def _kernel(x_ref, edges_ref, feat_ref, thresh_ref, leaf_ref, base_ref,
            out_ref):
    """x_ref (TILE_N, F); edges (F, B-1); feat/thresh (T, 2^d - 1);
    leaf (T, 2^d); base (1, K); out (TILE_N, K)."""
    x = x_ref[:]
    edges = edges_ref[:]
    # bin = #edges <= x, the exact integer searchsorted(side="right")
    xb = jnp.sum((edges[None, :, :] <= x[:, :, None]).astype(jnp.int32),
                 axis=-1)                                # (TILE_N, F)
    tables = NodeTables(feat=feat_ref[:], thresh=thresh_ref[:],
                        leaf=leaf_ref[:])
    out_ref[:] = table_logits(base_ref[0], tables, xb)


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def gbdt_logits_kernel(X: jax.Array, bin_edges: jax.Array,
                       feat: jax.Array, thresh: jax.Array,
                       leaf: jax.Array, base: jax.Array, *,
                       tile_n: int = 128,
                       interpret: bool = True) -> jax.Array:
    """X [N, F] raw features + NodeTables arrays -> logits [N, K].

    `feat`/`thresh` [T, 2^depth - 1] int32 and `leaf` [T, 2^depth] f32
    are the flattened tables from ``gbdt.node_tables`` (round-major tree
    axis); `bin_edges` [F, n_bins - 1]; `base` [K] initial logits."""
    N, F = X.shape
    K = base.shape[0]
    n_tiles = max((N + tile_n - 1) // tile_n, 1)
    pad_n = n_tiles * tile_n
    x = jnp.zeros((pad_n, F), jnp.float32).at[:N].set(
        X.astype(jnp.float32))

    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)  # noqa: E731
    edges = jnp.asarray(bin_edges, jnp.float32)
    feat = jnp.asarray(feat, jnp.int32)
    thresh = jnp.asarray(thresh, jnp.int32)
    leaf = jnp.asarray(leaf, jnp.float32)
    base2 = jnp.asarray(base, jnp.float32)[None, :]      # (1, K)

    out = pl.pallas_call(
        _kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_n, F), lambda i: (i, 0)),
                  full(edges), full(feat), full(thresh), full(leaf),
                  full(base2)],
        out_specs=pl.BlockSpec((tile_n, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_n, K), jnp.float32),
        interpret=interpret,
    )(x, edges, feat, thresh, leaf, base2)
    return out[:N]
