"""Pallas TPU kernel: fused 28-feature extraction for sliding windows.

AAPA's labeling/classification pipeline computes 28 statistical +
time-domain features per 60-minute window over ~300K windows (paper
§III.B). The pure-jnp path materializes a sorted copy, 29 shifted
autocorrelation products, and several moment intermediates per window in
HBM; this kernel fuses everything into one VMEM-resident pass per tile of
windows.

TPU mapping (see DESIGN.md §2 hardware-adaptation notes):
* grid over tiles of ``TILE_N`` windows; each block is a
  ``(TILE_N, PAD)`` f32 VMEM tile (PAD = window length padded to the
  64-lane boundary; windows are 60 samples, so one tile row = one window
  in lanes with a 4-lane sentinel pad).
* Order statistics (median / q25 / q75) need a sort, which the VPU lacks;
  instead we compute exact ranks with ``PAD-1`` static lane *rotations*
  and compare-accumulate — rank_i = #{j : x_j < x_i or (x_j == x_i and
  j < i)} — then select the k-th order statistic by masked sum. This keeps
  every intermediate rank-2 (sublane x lane), which Mosaic tiles natively;
  no rank-3 temporaries, no gather.
* Autocorrelations, diffs and peak counts reuse the same static-rotation
  trick with validity masks.

Everything here is also what ``ref.py``'s oracle
(``repro.core.features.stat_time_features``) computes; tests sweep shapes
and dtypes in interpret mode and assert allclose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-6
N_FEATS = 28
OUT_LANES = 32          # features padded to a lane-friendly width
ACF_LAGS = (1, 2, 3, 6, 12)
ACF_MAX_LO, ACF_MAX_HI = 2, 30
SENTINEL = 1e30


def _rotate(x, s):
    """Static rotate along the lane axis: out[:, i] = x[:, (i - s) % L]."""
    return jnp.roll(x, s, axis=1)


def _masked_acf(x, xc, mean, var, lag, w, lane):
    """Autocorrelation at `lag` over the valid prefix of length w."""
    shifted = _rotate(xc, -lag)                  # lane i holds xc[i + lag]
    valid = (lane < (w - lag)).astype(x.dtype)
    prod = jnp.sum(xc * shifted * valid, axis=1, keepdims=True)
    return prod / (w * var + EPS)


def _order_stat(x_sent, ranks, k, w):
    """k-th order statistic (0-based) via rank-match masked sum."""
    hit = (ranks == k).astype(x_sent.dtype)
    return jnp.sum(jnp.where(x_sent >= SENTINEL * 0.5, 0.0, x_sent) * hit,
                   axis=1, keepdims=True)


def _quantile(x_sent, ranks, q, w):
    pos = q * (w - 1)
    lo = int(pos)
    hi = min(lo + 1, w - 1)
    frac = pos - lo
    vlo = _order_stat(x_sent, ranks, lo, w)
    vhi = _order_stat(x_sent, ranks, hi, w)
    return vlo * (1.0 - frac) + vhi * frac


def _kernel(x_ref, o_ref, *, w: int):
    """x_ref: (TILE_N, PAD) f32, first `w` lanes valid; o_ref (TILE_N, 32)."""
    xr = x_ref[...]
    pad = xr.shape[1]
    lane = jax.lax.broadcasted_iota(jnp.int32, xr.shape, 1)
    valid = (lane < w)
    vf = valid.astype(xr.dtype)
    x = jnp.where(valid, xr, 0.0)

    n = float(w)
    mean = jnp.sum(x, axis=1, keepdims=True) / n
    xc = jnp.where(valid, x - mean, 0.0)
    var = jnp.sum(xc * xc, axis=1, keepdims=True) / n
    std = jnp.sqrt(var)
    cv = std / (mean + EPS)
    big = jnp.where(valid, x, -SENTINEL)
    xmax = jnp.max(big, axis=1, keepdims=True)
    xmin = jnp.min(jnp.where(valid, x, SENTINEL), axis=1, keepdims=True)

    # ---- exact ranks via static rotations (tie-break by lane index) ----
    x_sent = jnp.where(valid, x, SENTINEL)
    ranks = jnp.zeros_like(lane)
    for s in range(1, pad):
        xj = _rotate(x_sent, s)                 # lane i: x[(i - s) % pad]
        jlti = lane >= s                        # j = i - s (mod) < i
        less = (xj < x_sent) | ((xj == x_sent) & jlti)
        ranks = ranks + less.astype(jnp.int32)

    median = _quantile(x_sent, ranks, 0.50, w)
    q25 = _quantile(x_sent, ranks, 0.25, w)
    q75 = _quantile(x_sent, ranks, 0.75, w)
    iqr = q75 - q25

    m3 = jnp.sum(xc**3, axis=1, keepdims=True) / n
    m4 = jnp.sum(xc**4, axis=1, keepdims=True) / n
    skew = m3 / (var**1.5 + EPS)
    kurt = m4 / (var**2 + EPS) - 3.0
    max_to_median = xmax / (median + EPS)
    max_to_mean = xmax / (mean + EPS)
    zero_frac = jnp.sum((jnp.abs(x) <= EPS) * vf, axis=1, keepdims=True) / n
    rng_ = xmax - xmin

    # ---- trend (OLS vs lane index over valid prefix) ----
    t = lane.astype(xr.dtype)
    tbar = (n - 1.0) / 2.0
    tvar = (n * n - 1.0) / 12.0
    cov_tx = jnp.sum(jnp.where(valid, (t - tbar) * xc, 0.0), axis=1,
                     keepdims=True) / n
    slope = cov_tx / tvar
    slope_norm = slope / (mean + EPS)
    r2 = cov_tx * cov_tx / (tvar * var + EPS)
    half = w // 2
    sum_lo = jnp.sum(jnp.where(lane < half, x, 0.0), axis=1, keepdims=True)
    sum_hi = jnp.sum(jnp.where((lane >= half) & valid, x, 0.0), axis=1,
                     keepdims=True)
    half_ratio = (sum_hi / (n - half) + EPS) / (sum_lo / half + EPS)

    # ---- autocorrelations ----
    acf_named = [_masked_acf(x, xc, mean, var, k, w, lane)
                 for k in ACF_LAGS]
    acf_stack = [_masked_acf(x, xc, mean, var, k, w, lane)
                 for k in range(ACF_MAX_LO, ACF_MAX_HI + 1)]
    acf_all = jnp.concatenate(acf_stack, axis=1)       # (TILE_N, 29)
    acf_max = jnp.max(acf_all, axis=1, keepdims=True)
    acf_arg = (jnp.argmax(acf_all, axis=1, keepdims=True)
               .astype(xr.dtype) + ACF_MAX_LO) / ACF_MAX_HI

    # ---- diffs & peaks ----
    xn = _rotate(x, -1)                                # lane i: x[i+1]
    dvalid = (lane < (w - 1)).astype(xr.dtype)
    ad = jnp.abs(xn - x) * dvalid
    mean_ad = jnp.sum(ad, axis=1, keepdims=True) / (n - 1.0) / (mean + EPS)
    max_ad = jnp.max(ad, axis=1, keepdims=True) / (mean + EPS)

    xp = _rotate(x, 1)                                 # lane i: x[i-1]
    mid_ok = (lane >= 1) & (lane < (w - 1))
    thr = mean + std
    peaks = ((x > xp) & (x >= xn) & (x > thr) & mid_ok)
    n_peaks = jnp.sum(peaks.astype(xr.dtype), axis=1, keepdims=True) / n

    feats = jnp.concatenate(
        [mean, std, cv, xmin, xmax, median, q25, q75, iqr, skew, kurt,
         max_to_median, max_to_mean, zero_frac, rng_,
         slope_norm, r2, half_ratio,
         *acf_named, acf_max, acf_arg, mean_ad, max_ad, n_peaks], axis=1)
    o_ref[...] = jnp.pad(feats, ((0, 0), (0, OUT_LANES - N_FEATS)))


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def window_features_kernel(windows: jax.Array, *, tile_n: int = 256,
                           interpret: bool = True) -> jax.Array:
    """windows [N, W] (any float dtype) -> features [N, 28] f32.

    Pads N to a tile multiple and W to the 64-lane boundary; the pad region
    is masked inside the kernel.
    """
    N, W = windows.shape
    pad_w = max(64, ((W + 63) // 64) * 64)
    n_tiles = max((N + tile_n - 1) // tile_n, 1)
    pad_n = n_tiles * tile_n
    x = jnp.zeros((pad_n, pad_w), jnp.float32)
    x = x.at[:N, :W].set(windows.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel, w=W),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((tile_n, pad_w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile_n, OUT_LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pad_n, OUT_LANES), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:N, :N_FEATS]
