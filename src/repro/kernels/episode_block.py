"""Pallas TPU kernel: whole-episode fused plant + controller advance.

``plant_tick_block`` (kernels/plant_block.py) advances the decision-free
ticks of one control period in VMEM but returns to XLA at every block
head for ``controller.decide`` — so an M-minute episode still pays
M x ceil(60/ci) kernel-boundary round trips, and the controller
arithmetic never runs on-chip. This kernel fuses the entire episode:

* grid = (lane tiles, minutes); the minute axis is sequential per tile,
  so the plant lanes, the startup pipeline, the rate history ring and
  every controller-state leaf live in VMEM **scratch that persists
  across grid steps** — the whole episode advances without touching HBM
  except for the streams below;
* the rate trace streams in one minute-column per grid step and the 12
  per-minute aggregates stream out the same way (BlockSpec index maps
  give the automatic double-buffered DMA pipeline);
* at each control-period head the controller update runs *inside* the
  kernel: ``controller.decide`` vmapped over the lane tile (hpa / kpa /
  predictive are a handful of vector ops; AAPA's archetype strategy
  table is a select chain, and its reclassification descends the GBDT
  node tables — see kernels/gbdt_tables.py), with the cooldown /
  limiter state carried in the plant scratch columns.

Controllers are arbitrary closures over trained arrays (Table III,
forecaster seasonals, GBDT node tables), and Pallas kernels cannot
capture array constants — so the whole one-minute step is traced once
with ``jax.make_jaxpr`` and its captured constants are hoisted into
explicit kernel inputs that ride VMEM as full blocks shared by every
grid step (``jax.closure_convert`` is no help here: it hoists traced
values and deliberately leaves concrete arrays in the closure). Any
registry controller works unmodified.

The tick math is ``repro.sim.cluster``'s own shape-agnostic helpers
(`_pop_pipeline`, `_flow_tick`, `_apply_scaling`, `advance_plant`) and
the shared `apply_decision` limiter — the identical contraction-stable
float ops as the blocked scan, in the identical order, with the minute
accumulator folded tick-by-tick left-to-right. The CPU blocked scan
(``cluster.simulate``) is therefore the dispatch oracle this kernel is
pinned against: tests/test_kernel_smoke.py (deterministic, tier-1, all
five registry policies incl. AAPA-with-GBDT) and
tests/test_kernel_properties.py (random shapes, non-multiple-of-tile
lane counts). Compiled-program parity is ulp-tight, not bitwise — the
two paths are different XLA programs, so FMA contraction may differ
(see the `_flow_tick` stability note for why the drift stays ~1e-6).

Known real-TPU lowering gap (interpret mode is unaffected): an AAPA
reclassification stride that fires in-episode pulls
``jnp.fft.rfft`` (10 of the 38 features) into the kernel body, which
Mosaic does not lower today; the `requires_tpu` lane pins the policies
without that dependence and documents the rest.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.scaling.api import (Controller, LimiterState, Obs,
                               apply_decision)
from repro.sim.cluster import (MinuteOut, SimConfig, _acc_fold, _acc_init,
                               _apply_scaling, _flow_tick, _pop_pipeline,
                               advance_plant)

#: plant scratch column order (the limiter direction rides along because
#: the decide fused here is what reads/writes it)
PLANT_COLS = ("ready", "queue", "wait_sum", "util_ema", "cooldown",
              "pipe_sum", "last_dir")


def _make_minute_body(controller: Controller, cfg: SimConfig, tile_b: int,
                      init_leaves, blocks):
    """One minute for one lane tile as a pure function of the VMEM
    carry — the unit `jax.closure_convert` hoists the controller's
    closed-over arrays out of. `m == 0` selects the initial state
    (cluster.initial_state semantics), so episode start needs no
    separate init pass over the scratch."""
    decide_v = jax.vmap(controller.decide,
                        in_axes=(0, Obs(0, 0, 0, 0, 0, 0, None)))
    on_minute_v = jax.vmap(controller.on_minute, in_axes=(0, 0, None))
    treedef = jax.tree_util.tree_structure(controller.init())

    def minute_body(plant, pipe, hist, leaves, rate, m):
        first = m == 0
        z = jnp.zeros((tile_b,), jnp.float32)
        init_plant = jnp.stack(
            [jnp.full((tile_b,), float(cfg.initial_replicas), jnp.float32),
             z, z, jnp.full((tile_b,), 0.5, jnp.float32), z, z, z], axis=1)
        plant = jnp.where(first, init_plant, plant)
        pipe = jnp.where(first, 0.0, pipe)
        hist = jnp.where(first, 0.0, hist)
        leaves = tuple(
            jnp.where(first, jnp.broadcast_to(il, l.shape).astype(l.dtype),
                      l) for il, l in zip(init_leaves, leaves))

        arr = rate / 60.0
        ready, queue, wait_sum, util_ema, cool, pipe_sum, last_dir = (
            plant[:, k] for k in range(7))
        pipeline = pipe
        ctrl = jax.tree_util.tree_unflatten(treedef, leaves)
        acc = _acc_init()

        for n_ticks in blocks:
            # block head: decide once — the blocked scan's _ctrl_tick
            ready, pipeline, pipe_sum = _pop_pipeline(ready, pipeline,
                                                      pipe_sum)
            (queue, wait_sum, util_ema, served, violated, cold, resp,
             util) = _flow_tick(cfg, ready, queue, wait_sum, util_ema,
                                arr)
            total = ready + pipe_sum
            obs = Obs(ready_total=total, ready=ready, util_ema=util_ema,
                      queue=queue, rate_rps=arr, rate_history=hist,
                      minute_idx=m)
            ctrl, desired, cool_req = decide_v(ctrl, obs)
            desired = jnp.clip(jnp.asarray(desired, jnp.float32), 0.0,
                               cfg.max_replicas)
            cool_req = jnp.broadcast_to(
                jnp.asarray(cool_req, jnp.float32), desired.shape)
            lim, act = apply_decision(
                LimiterState(cooldown=cool, last_dir=last_dir), total,
                desired, cool_req, jnp.bool_(True), dt=1.0)
            cool, last_dir = lim.cooldown, lim.last_dir
            ready, pipeline, pipe_sum = _apply_scaling(
                ready, pipeline, pipe_sum, act)
            acc = _acc_fold(acc, (served, violated, cold,
                                  ready + pipe_sum, resp, util,
                                  act.scale_up.astype(jnp.float32),
                                  act.scale_down.astype(jnp.float32),
                                  act.oscillation, ready))
            # the rest of the block is pure plant dynamics
            if n_ticks > 1:
                (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
                 cool), acc = advance_plant(
                    cfg, ready, pipeline, pipe_sum, queue, wait_sum,
                    util_ema, cool, acc, arr, n_ticks - 1)

        # minute boundary: history push + hook (cluster._finish_minute)
        hist = jnp.concatenate([hist[:, 1:], rate[:, None]], axis=1)
        ctrl = on_minute_v(ctrl, hist, m + 1)

        plant = jnp.stack([ready, queue, wait_sum, util_ema, cool,
                           pipe_sum, last_dir], axis=1)
        leaves_out = tuple(
            o.astype(l.dtype) for o, l in
            zip(jax.tree_util.tree_leaves(ctrl), leaves))
        outs = (acc[0], acc[1], acc[2], acc[3], queue, acc[4], acc[5],
                acc[6], acc[7], acc[8], acc[9] / 60.0, acc[10] / 60.0)
        return plant, pipeline, hist, leaves_out, outs

    return minute_body


def _hoist(fun, example_args):
    """Trace `fun` once over `example_args` (avals) and return
    ``(call, consts)`` where `call(args, consts)` evaluates the traced
    jaxpr with the captured array constants passed explicitly — the
    closure conversion Pallas needs (`jax.closure_convert` keeps
    concrete arrays in the closure, which pallas_call rejects)."""
    flat_ex, in_tree = jax.tree_util.tree_flatten(tuple(example_args))
    out_tree_box = []

    def flat_fun(*flat_args):
        args = jax.tree_util.tree_unflatten(in_tree, flat_args)
        flat_out, out_tree = jax.tree_util.tree_flatten(fun(*args))
        out_tree_box.append(out_tree)
        return flat_out

    closed = jax.make_jaxpr(flat_fun)(*flat_ex)
    out_tree = out_tree_box[0]

    def call(args, consts):
        flat_args, _ = jax.tree_util.tree_flatten(tuple(args))
        out_flat = jax.core.eval_jaxpr(closed.jaxpr, list(consts),
                                       *flat_args)
        return jax.tree_util.tree_unflatten(out_tree, out_flat)

    return call, closed.consts


def _episode_kernel(rate_ref, *refs, minute_conv, const_shapes,
                    n_leaves):
    """One grid step = one minute for one lane tile. refs order: hoisted
    closure constants, 12 MinuteOut column outputs, then scratch (plant
    (TILE_B, 7) in PLANT_COLS order, pipeline (TILE_B, S), history ring
    (TILE_B, H), one buffer per controller-state leaf)."""
    n_consts = len(const_shapes)
    const_refs = refs[:n_consts]
    out_refs = refs[n_consts:n_consts + 12]
    plant_ref, pipe_ref, hist_ref = refs[n_consts + 12:n_consts + 15]
    ctrl_refs = refs[n_consts + 15:]
    m = pl.program_id(1)

    consts = [r[:].reshape(s) for r, s in zip(const_refs, const_shapes)]
    leaves = tuple(r[:] for r in ctrl_refs)
    plant, pipe, hist, leaves, outs = minute_conv(
        (plant_ref[:], pipe_ref[:], hist_ref[:], leaves,
         rate_ref[:, 0], m), consts)

    plant_ref[:] = plant
    pipe_ref[:] = pipe
    hist_ref[:] = hist
    for r, leaf in zip(ctrl_refs, leaves):
        r[:] = leaf
    for r, v in zip(out_refs, outs):
        r[:, 0] = v


def episode_minutes(controller: Controller, cfg: SimConfig,
                    rates: jax.Array, *, tile_b: int = 8,
                    interpret: bool = True) -> MinuteOut:
    """Run whole episodes on-chip: rates [B, M] -> MinuteOut of [B, M].

    Lane b reproduces ``cluster.simulate(rates[b], controller, cfg)`` to
    compiled-program (ulp) tolerance. B pads to a multiple of `tile_b`
    (padding lanes simulate a zero-rate workload and are sliced off)."""
    rates = jnp.asarray(rates, jnp.float32)
    B, M = rates.shape
    S = int(cfg.startup_sec)
    H = int(cfg.history_len)
    ci = max(min(int(cfg.control_interval_sec), 60), 1)
    n_full = 60 // ci
    tail = 60 - n_full * ci
    blocks = tuple([ci] * n_full + ([tail] if tail else []))

    init_leaves, _ = jax.tree_util.tree_flatten(controller.init())
    init_leaves = [jnp.asarray(leaf) for leaf in init_leaves]

    n_tiles = max((B + tile_b - 1) // tile_b, 1)
    pad_b = n_tiles * tile_b
    rp = jnp.zeros((pad_b, M), jnp.float32).at[:B].set(rates)

    # hoist every array the controller closes over (Table III, GBDT node
    # tables, forecaster seasonals, init buffers) into explicit inputs
    minute_body = _make_minute_body(controller, cfg, tile_b, init_leaves,
                                    blocks)
    lv = lambda leaf: jax.ShapeDtypeStruct((tile_b,) + leaf.shape,  # noqa: E731
                                           leaf.dtype)
    examples = (jax.ShapeDtypeStruct((tile_b, 7), jnp.float32),
                jax.ShapeDtypeStruct((tile_b, S), jnp.float32),
                jax.ShapeDtypeStruct((tile_b, H), jnp.float32),
                tuple(lv(leaf) for leaf in init_leaves),
                jax.ShapeDtypeStruct((tile_b,), jnp.float32),
                jax.ShapeDtypeStruct((), jnp.int32))
    minute_conv, consts = _hoist(minute_body, examples)
    const_shapes = tuple(jnp.shape(c) for c in consts)
    # every const becomes a leading-1 "tile" broadcast to all grid steps
    const_in = [jnp.reshape(c, (1,) + (jnp.shape(c) or (1,)))
                for c in consts]
    const_specs = [
        pl.BlockSpec(a.shape, functools.partial(
            lambda nd, i, m: (0,) * nd, a.ndim)) for a in const_in]

    col = pl.BlockSpec((tile_b, 1), lambda i, m: (i, m))
    scratch = [pltpu.VMEM((tile_b, 7), jnp.float32),
               pltpu.VMEM((tile_b, S), jnp.float32),
               pltpu.VMEM((tile_b, H), jnp.float32)]
    scratch += [pltpu.VMEM((tile_b,) + leaf.shape, leaf.dtype)
                for leaf in init_leaves]

    outs = pl.pallas_call(
        functools.partial(_episode_kernel, minute_conv=minute_conv,
                          const_shapes=const_shapes,
                          n_leaves=len(init_leaves)),
        grid=(n_tiles, M),
        in_specs=[col] + const_specs,
        out_specs=[col] * 12,
        out_shape=[jax.ShapeDtypeStruct((pad_b, M), jnp.float32)] * 12,
        scratch_shapes=scratch,
        interpret=interpret,
    )(rp, *const_in)
    return MinuteOut(*(o[:B] for o in outs))
