"""Serving engine: continuous-batching decode over replica lanes, with the
AAPA autoscaler as the replica control plane.

A *replica* is one model instance with `lanes` concurrent decode slots
(continuous batching). The engine keeps a FIFO of requests; each engine
step admits requests to free slots across all ready replicas, runs one
batched decode step, and retires finished sequences. Replica counts come
from an autoscaling Controller fed with the observed arrival trace — this
is the paper's system applied to model serving (DESIGN.md §2).

Pod startup latency is modelled (a replica added at t serves from
t + startup). On this CPU container the model is a reduced config; on TPU
the same engine drives pjit-sharded decode_step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float            # seconds
    prompt_len: int
    gen_len: int
    start: float = -1.0
    finish: float = -1.0
    tokens_done: int = 0
    slot: int = -1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    slo_violations: int = 0
    cold_starts: int = 0
    latencies_ms: list = dataclasses.field(default_factory=list)
    replica_seconds: float = 0.0
    steps: int = 0


class ServingEngine:
    """Discrete-time engine: step() advances one decode tick."""

    def __init__(self, cfg, params, *, lanes_per_replica: int = 4,
                 max_replicas: int = 8, max_len: int = 64,
                 step_time_s: float = 0.05, startup_s: float = 2.0,
                 slo_s: float = 1.0):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes_per_replica
        self.max_replicas = max_replicas
        self.max_len = max_len
        self.step_time = step_time_s
        self.startup_s = startup_s
        self.slo_s = slo_s

        self.t = 0.0
        self.ready_replicas = 1
        self.starting: list[float] = []     # ready-at times
        self.queue: deque[Request] = deque()
        n_slots = max_replicas * lanes_per_replica
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.active: dict[int, Request] = {}   # slot -> request
        self.stats = EngineStats()

        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    # ------------------------------------------------------------- control
    def scale_to(self, desired: int) -> None:
        desired = int(np.clip(desired, 1, self.max_replicas))
        total = self.ready_replicas + len(self.starting)
        if desired > total:
            for _ in range(desired - total):
                self.starting.append(self.t + self.startup_s)
        elif desired < total:
            drop = total - desired
            while drop and self.starting:
                self.starting.pop()
                drop -= 1
            self.ready_replicas = max(self.ready_replicas - drop, 1)

    # --------------------------------------------------------------- step
    def submit(self, req: Request) -> None:
        if self.ready_replicas == 0 and not self.active:
            self.stats.cold_starts += 1
        self.queue.append(req)

    def step(self) -> None:
        # pods finishing startup
        still = []
        for ready_at in self.starting:
            if ready_at <= self.t:
                self.ready_replicas += 1
            else:
                still.append(ready_at)
        self.starting = still

        n_slots = self.ready_replicas * self.lanes
        # admit queued requests to free slots
        free = [s for s in range(n_slots) if s not in self.active]
        while self.queue and free:
            req = self.queue.popleft()
            req.slot = free.pop(0)
            req.start = self.t
            self.active[req.slot] = req

        if self.active:
            # one decode step for every active slot (continuous batching)
            total_slots = self.max_replicas * self.lanes
            toks = np.zeros((total_slots, 1), np.int32)
            for s, req in self.active.items():
                toks[s, 0] = 1 + (req.tokens_done % 7)
            pos = jnp.int32(int(min(self.t / self.step_time,
                                    self.max_len - 1)) % self.max_len)
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks), pos)
            done = []
            for s, req in self.active.items():
                req.tokens_done += 1
                if req.tokens_done >= req.gen_len:
                    req.finish = self.t + self.step_time
                    lat = req.finish - req.arrival
                    self.stats.latencies_ms.append(lat * 1e3)
                    self.stats.served += 1
                    if lat > self.slo_s:
                        self.stats.slo_violations += 1
                    done.append(s)
            for s in done:
                del self.active[s]

        self.stats.replica_seconds += (self.ready_replicas
                                       + len(self.starting)) \
            * self.step_time
        self.stats.steps += 1
        self.t += self.step_time

    # ------------------------------------------------------------ metrics
    def observed_rate(self, window_s: float = 60.0) -> float:
        recent = [r for r in self.stats.latencies_ms]
        return len(recent) / max(self.t, 1e-9)

    def summary(self) -> dict:
        lat = np.asarray(self.stats.latencies_ms)
        return {
            "served": self.stats.served,
            "slo_violation_rate": (self.stats.slo_violations
                                   / max(self.stats.served, 1)),
            "cold_starts": self.stats.cold_starts,
            "p50_ms": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p95_ms": float(np.percentile(lat, 95)) if len(lat) else 0.0,
            "replica_seconds": self.stats.replica_seconds,
            "queue_len": len(self.queue),
        }
