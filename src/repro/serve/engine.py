"""Serving engine: continuous-batching decode over replica lanes.

A *replica* is one model instance with `lanes` concurrent decode slots
(continuous batching). The engine keeps a FIFO of requests; each engine
step admits requests to free slots across all ready replicas, runs one
batched decode step, and retires finished sequences. The engine is a pure
plant: replica counts come from `engine.scale_to`, normally driven by a
`repro.scaling` Controller through `repro.scaling.adapter.EngineAutoscaler`
— the same policies (and the same cooldown semantics) that run compiled
inside the cluster simulator.

Idle semantics match the simulator: `scale_to(0)` is honored (scale to
zero), and a request arriving with zero ready replicas counts as a cold
start and wakes the endpoint through the activator (one replica starts if
none is already starting).

Pod startup latency is modelled (a replica added at t serves from
t + startup). On this CPU container the model is a reduced config; on TPU
the same engine drives pjit-sharded decode_step.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float            # seconds
    prompt_len: int
    gen_len: int
    start: float = -1.0
    finish: float = -1.0
    tokens_done: int = 0
    slot: int = -1


@dataclasses.dataclass
class EngineStats:
    served: int = 0
    slo_violations: int = 0
    cold_starts: int = 0
    latencies_ms: list = dataclasses.field(default_factory=list)
    replica_seconds: float = 0.0
    steps: int = 0


class ServingEngine:
    """Discrete-time engine: step() advances one decode tick."""

    def __init__(self, cfg, params, *, lanes_per_replica: int = 4,
                 max_replicas: int = 8, max_len: int = 64,
                 step_time_s: float = 0.05, startup_s: float = 2.0,
                 slo_s: float = 1.0, activator: bool = True):
        self.cfg = cfg
        self.params = params
        self.lanes = lanes_per_replica
        self.max_replicas = max_replicas
        self.max_len = max_len
        self.step_time = step_time_s
        self.startup_s = startup_s
        self.slo_s = slo_s
        self.activator = activator

        self.t = 0.0
        self.ready_replicas = 1
        self.starting: list[float] = []     # ready-at times
        self.queue: deque[Request] = deque()
        self.arrivals_total = 0             # monotonic arrival counter
        self._arrival_times: deque[float] = deque()  # for observed_rate
        n_slots = max_replicas * lanes_per_replica
        self.cache = M.init_cache(cfg, n_slots, max_len)
        self.active: dict[int, Request] = {}   # slot -> request
        self.stats = EngineStats()

        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    # ------------------------------------------------------------- control
    def scale_to(self, desired: int) -> None:
        """Honors 0 (scale-to-zero): starting pods cancel first, then
        ready pods drain — matching the simulator's idle semantics."""
        desired = int(np.clip(desired, 0, self.max_replicas))
        total = self.ready_replicas + len(self.starting)
        if desired > total:
            for _ in range(desired - total):
                self.starting.append(self.t + self.startup_s)
        elif desired < total:
            drop = total - desired
            while drop and self.starting:
                self.starting.pop()
                drop -= 1
            self.ready_replicas = max(self.ready_replicas - drop, 0)

    # --------------------------------------------------------------- step
    def submit(self, req: Request) -> None:
        # every arrival with zero ready pods experiences a cold start
        # (same accounting as the simulator); the activator wakes the
        # endpoint if nothing is already starting.
        if self.ready_replicas == 0:
            self.stats.cold_starts += 1
            if self.activator and not self.starting:
                self.starting.append(self.t + self.startup_s)
        self.arrivals_total += 1
        # record the submission time, not the caller-supplied arrival
        # field: observed_rate's windowing needs monotonic timestamps
        self._arrival_times.append(self.t)
        self.queue.append(req)

    def step(self) -> None:
        # pods finishing startup
        still = []
        for ready_at in self.starting:
            if ready_at <= self.t:
                self.ready_replicas += 1
            else:
                still.append(ready_at)
        self.starting = still

        n_slots = self.ready_replicas * self.lanes
        # admit queued requests to free slots
        free = [s for s in range(n_slots) if s not in self.active]
        while self.queue and free:
            req = self.queue.popleft()
            req.slot = free.pop(0)
            req.start = self.t
            self.active[req.slot] = req

        if self.active:
            # one decode step for every active slot (continuous batching)
            total_slots = self.max_replicas * self.lanes
            toks = np.zeros((total_slots, 1), np.int32)
            for s, req in self.active.items():
                toks[s, 0] = 1 + (req.tokens_done % 7)
            pos = jnp.int32(int(min(self.t / self.step_time,
                                    self.max_len - 1)) % self.max_len)
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks), pos)
            done = []
            for s, req in self.active.items():
                req.tokens_done += 1
                if req.tokens_done >= req.gen_len:
                    req.finish = self.t + self.step_time
                    lat = req.finish - req.arrival
                    self.stats.latencies_ms.append(lat * 1e3)
                    self.stats.served += 1
                    if lat > self.slo_s:
                        self.stats.slo_violations += 1
                    done.append(s)
            for s in done:
                del self.active[s]

        # bill ready + starting pods, plus draining capacity: replicas
        # removed by scale_to keep finishing their in-flight requests
        # (graceful drain) and that time is still paid for
        draining = max(-(-len(self.active) // self.lanes)
                       - self.ready_replicas, 0)
        self.stats.replica_seconds += (self.ready_replicas + draining
                                       + len(self.starting)) \
            * self.step_time
        self.stats.steps += 1
        self.t += self.step_time

    # ------------------------------------------------------------ metrics
    RATE_RETENTION_S = 600.0   # longest window observed_rate supports

    def observed_rate(self, window_s: float = 60.0) -> float:
        """True sliding-window arrival rate (req/s over the trailing
        `window_s`, or over the elapsed time when younger than that).
        Non-destructive for any window up to RATE_RETENTION_S, so mixed
        window sizes may be queried in any order; larger windows clamp
        to the retention horizon."""
        window_s = min(window_s, self.RATE_RETENTION_S)
        keep_cutoff = self.t - self.RATE_RETENTION_S
        while self._arrival_times and self._arrival_times[0] < keep_cutoff:
            self._arrival_times.popleft()
        cutoff = self.t - window_s
        count = 0
        for a in reversed(self._arrival_times):
            if a < cutoff:
                break
            count += 1
        horizon = min(window_s, max(self.t, self.step_time))
        return count / horizon

    def summary(self) -> dict:
        lat = np.asarray(self.stats.latencies_ms)
        return {
            "served": self.stats.served,
            "slo_violation_rate": (self.stats.slo_violations
                                   / max(self.stats.served, 1)),
            "cold_starts": self.stats.cold_starts,
            "p50_ms": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p95_ms": float(np.percentile(lat, 95)) if len(lat) else 0.0,
            "replica_seconds": self.stats.replica_seconds,
            "queue_len": len(self.queue),
        }
