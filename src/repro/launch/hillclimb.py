import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# §Perf hillclimb driver: probe the three selected cells with candidate
# changes (baseline vs variant), writing before/after roofline terms to
# experiments/hillclimb/results.json.
#
#   cell A (paper-representative serving decode): stablelm_1_6b decode_32k
#           — variant: fp8 KV cache (memory term / 2 on the cache reads)
#   cell B (sub-quadratic long-context): zamba2_2_7b long_500k
#           — variant: fp8 shared-attn KV cache
#   cell C (most collective-bound / MoE): qwen3_moe_30b_a3b train_4k
#           — variant: capacity_factor 2.0 -> 1.0 (a2a bytes ~ -50%)

import json
import pathlib

from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import probe_cell

CELLS = [
    ("stablelm_1_6b", "decode_32k", "fp8_kv_cache",
     {"cache_dtype": "float8_e4m3fn"}),
    ("zamba2_2_7b", "long_500k", "fp8_kv_cache",
     {"cache_dtype": "float8_e4m3fn"}),
    ("qwen3_moe_30b_a3b", "train_4k", "capacity_factor_1.0",
     {"capacity_factor": 1.0}),
]


def main():
    out = pathlib.Path("experiments/hillclimb")
    out.mkdir(parents=True, exist_ok=True)
    path = out / "results.json"
    results = json.loads(path.read_text()) if path.exists() else {}
    mesh = make_production_mesh(multi_pod=False)

    for arch, shape, vname, overrides in CELLS:
        for tag, ov in (("baseline", None), (vname, overrides)):
            key = f"{arch}|{shape}|{tag}"
            if key in results and "error" not in results[key]:
                print("[hillclimb] cached", key)
                continue
            try:
                rec = probe_cell(arch, shape, mesh, cfg_overrides=ov)
                print(f"[hillclimb] {key}: comp={rec['compute_s']:.3e} "
                      f"mem={rec['memory_s']:.3e} "
                      f"coll={rec['collective_s']:.3e} "
                      f"dom={rec['dominant']}")
            except Exception as e:
                import traceback
                traceback.print_exc()
                rec = {"error": str(e)}
            results[key] = rec
            path.write_text(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
