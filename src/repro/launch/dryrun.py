import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape)
# cell on the production mesh, print memory/cost analyses, and dump
# roofline inputs to JSON.
#
# Usage:
#     PYTHONPATH=src python -m repro.launch.dryrun \
#         --arch all --shape all --mesh both --out experiments/dryrun
#
# The XLA_FLAGS lines above MUST run before any other import (jax locks
# the device count on first init) — which is why this module has no
# `from __future__` header.

import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs.registry import SHAPES, cells, get_config
from repro.dist import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh

# collective-op byte accounting (per-device module; see EXPERIMENTS.md).
_COLL_RE = re.compile(
    r"^\s*\S+ = \(?([a-z0-9]+\[[0-9,]*\])"
    r".*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
                "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device collective bytes by op kind from compiled HLO.

    Uses result shapes with op-specific traffic factors (ring algorithms):
    all-reduce 2(g-1)/g * R, all-gather (g-1)/g * R, reduce-scatter
    (g-1) * R (operand ~ g*R), all-to-all (g-1)/g * R, permute R.
    """
    out = {k: 0.0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.match(line)
        if not m:
            continue
        op = m.group(2)
        # sum every result-tuple component on the line (variadic collectives)
        lhs = line.split("=", 1)[1].split("(", 1)[0]
        bytes_ = sum(_shape_bytes(t.group(0))
                     for t in _SHAPE_RE.finditer(lhs))
        g = 2.0
        gm = _GROUP_RE.search(line)
        if gm:
            g = max(float(gm.group(2)), 2.0)
        if op == "all-reduce":
            traffic = 2.0 * bytes_ * (g - 1.0) / g
        elif op == "all-gather":
            traffic = bytes_ * (g - 1.0) / g
        elif op == "reduce-scatter":
            traffic = bytes_ * (g - 1.0)
        elif op == "all-to-all":
            traffic = bytes_ * (g - 1.0) / g
        else:
            traffic = bytes_
        out[op] += traffic
        counts[op] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_dir: pathlib.Path | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.set_mesh(mesh)
    try:
        fn, args = sp.step_fn(cfg, shape, dp_size=rules.axis_size("dp"))
        if shape.kind == "train":
            params_s, opt_s, batch_s = args
            in_sh = (shd.param_shardings(params_s),
                     jax.tree.map(lambda _: None, opt_s),
                     shd.batch_shardings(batch_s))
            # opt state shards like the master params
            in_sh = (in_sh[0],
                     type(opt_s)(None, shd.param_shardings(opt_s.master),
                                 shd.param_shardings(opt_s.m),
                                 shd.param_shardings(opt_s.v)),
                     in_sh[2])
        elif shape.kind == "prefill":
            params_s, batch_s = args
            in_sh = (shd.param_shardings(params_s),
                     shd.batch_shardings(batch_s))
        else:
            params_s, cache_s, tok_s = args
            in_sh = (shd.param_shardings(params_s),
                     shd.cache_shardings(cache_s, cfg),
                     shd.batch_shardings({"tokens": tok_s})["tokens"])

        # donation: train updates (params, opt) in place; decode updates the
        # cache in place — halves the resident footprint of the updated state
        donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
        t0 = time.time()
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        if hlo_dir is not None:
            hlo_dir.mkdir(parents=True, exist_ok=True)
            tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
            (hlo_dir / f"{tag}.hlo.txt").write_text(hlo)

        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": mesh.devices.size,
            "kind": shape.kind,
            "ok": True,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "flops_per_device": float(cost.get("flops", -1.0)),
            "bytes_accessed_per_device": float(
                cost.get("bytes accessed", -1.0)),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", 0),
            },
            "collectives": coll,
            "param_count": cfg.param_count(),
            "active_param_count": cfg.active_param_count(),
        }
        print(f"[dryrun] {arch} {shape_name} "
              f"{'multi' if multi_pod else 'single'}: OK "
              f"compile={t_compile:.0f}s flops/dev={rec['flops_per_device']:.3e} "
              f"coll={coll['total_bytes']:.3e}B")
        print(f"  memory_analysis: {rec['memory']}")
        return rec
    except Exception as e:  # a failing cell is a bug — record it loudly
        traceback.print_exc()
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "ok": False, "error": f"{type(e).__name__}: {e}"}
    finally:
        shd.set_mesh(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    hlo_dir = out_dir / "hlo" if args.save_hlo else None

    todo = cells()
    if args.arch != "all":
        todo = [(a, s) for a, s in todo if a == args.arch]
    if args.shape != "all":
        todo = [(a, s) for a, s in todo if s == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results_path = out_dir / "results.json"
    results = {}
    if results_path.exists():
        results = json.loads(results_path.read_text())

    for arch, shape in todo:
        for mp in meshes:
            key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
            if results.get(key, {}).get("ok"):
                print(f"[dryrun] skip cached {key}")
                continue
            results[key] = run_cell(arch, shape, mp, hlo_dir)
            results_path.write_text(json.dumps(results, indent=1))

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"[dryrun] {n_ok}/{len(results)} cells OK -> {results_path}")


if __name__ == "__main__":
    main()
