"""ShapeDtypeStruct stand-ins for every model input (no device allocation)
plus the functions the dry-run lowers: train_step / prefill / decode.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.train_step import make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the model-input batch of a given shape cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        n_text = S - (cfg.n_img_tokens or 0)
        batch = {"tokens": _sds((B, n_text), jnp.int32),
                 "labels": _sds((B, n_text), jnp.int32)}
        if cfg.n_img_tokens:
            batch["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model),
                                       cfg.jdtype)
        if cfg.family == "encdec":
            batch["enc_embeds"] = _sds((B, cfg.enc_len, cfg.d_model),
                                       cfg.jdtype)
        return batch
    if shape.kind == "prefill":
        n_text = S - (cfg.n_img_tokens or 0)
        batch = {"tokens": _sds((B, n_text), jnp.int32)}
        if cfg.n_img_tokens:
            batch["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model),
                                       cfg.jdtype)
        if cfg.family == "encdec":
            batch["enc_embeds"] = _sds((B, cfg.enc_len, cfg.d_model),
                                       cfg.jdtype)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((B, 1), jnp.int32)}


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(partial(M.init, cfg=cfg), jax.random.PRNGKey(0))


def opt_specs(cfg: ModelConfig):
    params = param_specs(cfg)
    return jax.eval_shape(opt_lib.init, params)


def cache_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Decode-shape KV/state cache ShapeDtypeStructs (seq_len deep)."""
    return jax.eval_shape(
        partial(M.init_cache, cfg, shape.global_batch, shape.seq_len))


def train_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                       dp_size: int, *, stash_budget: float = 2e9) -> int:
    """Gradient-accumulation depth chosen so the per-device remat stash
    (n_layers x live-tokens x d_model x 2B) fits the budget. Power of two,
    capped so each microbatch still has >= 1 sequence per data shard."""
    tokens_loc = shape.global_batch * shape.seq_len / max(dp_size, 1)
    width = cfg.d_model * (cfg.expand if cfg.family in ("ssm", "hybrid")
                           else 1)
    stash = cfg.n_layers * tokens_loc * width * 2.0
    mb, cap = 1, max(shape.global_batch // max(dp_size, 1), 1)
    while stash / mb > stash_budget and mb < cap:
        mb *= 2
    return mb


def step_fn(cfg: ModelConfig, shape: ShapeSpec, *, dp_size: int = 16,
            microbatches: int | None = None):
    """The function a dry-run cell lowers, plus its abstract args."""
    if shape.kind == "train":
        mb = (microbatches if microbatches is not None
              else train_microbatches(cfg, shape, dp_size))
        ts = make_train_step(cfg, microbatches=mb)
        args = (param_specs(cfg), opt_specs(cfg), input_specs(cfg, shape))
        return ts, args
    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return M.prefill(params, batch, cfg, max_len=shape.seq_len)
        return prefill_fn, (param_specs(cfg), input_specs(cfg, shape))
    # decode
    def decode_fn(params, cache, tokens):
        pos = jnp.int32(shape.seq_len - 1)
        return M.decode_step(params, cache, tokens, pos, cfg)
    return decode_fn, (param_specs(cfg), cache_specs(cfg, shape),
                       input_specs(cfg, shape)["tokens"])
