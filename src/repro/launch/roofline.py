import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# Roofline probes (single-pod mesh, per §Roofline):
#
#   compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
#   memory term     = HLO_bytes / (chips x 819 GB/s HBM)
#   collective term = collective_bytes / (chips x 50 GB/s ICI link)
#
# XLA's cost_analysis counts a while/scan body ONCE regardless of trip
# count, so every production function is re-lowered here in "unroll" mode
# (straight-line layers / flash tiles / SSD chunks / CE chunks) at two
# layer counts; the per-layer delta + fixed cost extrapolate exactly to
# the full depth. Collective bytes are parsed from the unrolled sharded
# HLO the same way. The scanned production artifact (launch/dryrun.py)
# separately proves compile + memory feasibility.

import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro.configs.registry import SHAPES, cells, get_config
from repro.dist import sharding as shd
from repro.launch import specs as sp
from repro.launch.dryrun import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import layers as Lyr

PEAK_FLOPS = 197e12       # bf16 per chip (v5e)
HBM_BW = 819e9            # per chip
LINK_BW = 50e9            # per ICI link


def _probe_cfg(cfg, n_scan, n_enc=None):
    kw = {"n_layers": cfg.first_k_dense + n_scan}
    if n_enc is not None:
        kw["n_enc_layers"] = n_enc
    return dataclasses.replace(cfg, **kw)


def _lower_probe(cfg, shape, mesh):
    """Lower+compile one unrolled probe; return (flops, bytes, coll)."""
    rules = shd.set_mesh(mesh)
    Lyr.set_unroll(True)
    try:
        fn, args = sp.step_fn(cfg, shape, dp_size=rules.axis_size("dp"),
                              microbatches=1)
        if shape.kind == "train":
            params_s, opt_s, batch_s = args
            in_sh = (shd.param_shardings(params_s),
                     type(opt_s)(None, shd.param_shardings(opt_s.master),
                                 shd.param_shardings(opt_s.m),
                                 shd.param_shardings(opt_s.v)),
                     shd.batch_shardings(batch_s))
        elif shape.kind == "prefill":
            in_sh = (shd.param_shardings(args[0]),
                     shd.batch_shardings(args[1]))
        else:
            in_sh = (shd.param_shardings(args[0]),
                     shd.cache_shardings(args[1], cfg),
                     shd.batch_shardings({"tokens": args[2]})["tokens"])
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        cost = compiled.cost_analysis()
        coll = parse_collectives(compiled.as_text())
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(coll["total_bytes"])}
    finally:
        Lyr.set_unroll(False)
        shd.set_mesh(None)


def _extrapolate(lo, hi, l_lo, l_hi, l_full):
    out = {}
    for k in lo:
        per = (hi[k] - lo[k]) / (l_hi - l_lo)
        fixed = lo[k] - l_lo * per
        out[k] = max(fixed + l_full * per, 0.0)
        out[k + "_per_layer"] = per
        out[k + "_fixed"] = fixed
    return out


def probe_cell(arch: str, shape_name: str, mesh, *,
               cfg_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    t0 = time.time()
    if cfg.family == "encdec":
        rb = _lower_probe(_probe_cfg(cfg, 2, n_enc=2), shape, mesh)
        re = _lower_probe(_probe_cfg(cfg, 2, n_enc=4), shape, mesh)
        rd = _lower_probe(_probe_cfg(cfg, 4, n_enc=2), shape, mesh)
        full = {}
        for k in rb:
            enc_per = (re[k] - rb[k]) / 2.0
            dec_per = (rd[k] - rb[k]) / 2.0
            fixed = rb[k] - 2 * enc_per - 2 * dec_per
            full[k] = max(fixed + cfg.n_enc_layers * enc_per
                          + cfg.n_layers * dec_per, 0.0)
            full[k + "_per_layer"] = dec_per
            full[k + "_fixed"] = fixed
    else:
        l_lo = cfg.attn_every if cfg.family == "hybrid" else 2
        l_hi = 2 * l_lo
        lo = _lower_probe(_probe_cfg(cfg, l_lo), shape, mesh)
        hi = _lower_probe(_probe_cfg(cfg, l_hi), shape, mesh)
        n_full = cfg.n_layers - cfg.first_k_dense
        full = _extrapolate(lo, hi, l_lo, l_hi, n_full)

    chips = mesh.devices.size
    compute_t = full["flops"] / PEAK_FLOPS          # flops are per-device
    memory_t = full["bytes"] / HBM_BW
    coll_t = full["coll"] / LINK_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N*D train, 2*N*D forward (prefill/decode); MoE: active
    n_active = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)
    mult = 6.0 if shape.kind == "train" else 2.0
    model_flops = mult * n_active * tokens
    hlo_flops_global = full["flops"] * chips
    bound = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        "flops_per_device": full["flops"],
        "bytes_per_device": full["bytes"],
        "coll_bytes_per_device": full["coll"],
        "compute_s": compute_t, "memory_s": memory_t,
        "collective_s": coll_t,
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (model_flops / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "roofline_fraction": (compute_t / bound if bound else 0.0),
        "step_time_bound_s": bound,
        "probe_s": round(time.time() - t0, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    results_path = out_dir / "results.json"
    results = {}
    if results_path.exists():
        results = json.loads(results_path.read_text())

    mesh = make_production_mesh(multi_pod=False)
    todo = cells()
    if args.arch != "all":
        todo = [(a, s) for a, s in todo if a == args.arch]
    if args.shape != "all":
        todo = [(a, s) for a, s in todo if s == args.shape]

    for arch, shape in todo:
        key = f"{arch}|{shape}"
        if key in results and "error" not in results[key]:
            print(f"[roofline] skip cached {key}")
            continue
        try:
            rec = probe_cell(arch, shape, mesh)
            print(f"[roofline] {key}: dom={rec['dominant']} "
                  f"comp={rec['compute_s']:.2e}s mem={rec['memory_s']:.2e}s "
                  f"coll={rec['collective_s']:.2e}s "
                  f"useful={rec['useful_flop_ratio']:.2f} "
                  f"({rec['probe_s']}s)")
        except Exception as e:
            import traceback
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape,
                   "error": f"{type(e).__name__}: {e}"}
        results[key] = rec
        results_path.write_text(json.dumps(results, indent=1))

    print(f"[roofline] -> {results_path}")


if __name__ == "__main__":
    main()
