"""Production training launcher: --arch x --shape on the production mesh.

On a real TPU pod slice each host runs:

    python -m repro.launch.train --arch deepseek_67b --shape train_4k \
        --coordinator $COORD --num-hosts $N --host-id $ID

On this CPU container use --dry-run (lower+compile only) or --local-smoke
(reduced config, real steps on 1 device).
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--local-smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="experiments/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    # multi-host bring-up (jax.distributed)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"

    import jax

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    import numpy as np
    import jax.numpy as jnp
    from repro.configs import get_config, smoke_config
    from repro.models import model as M
    from repro.train import checkpoint as ckpt
    from repro.train import optimizer as opt_lib
    from repro.train.train_step import make_train_step

    if args.dry_run:
        from repro.launch.dryrun import run_cell
        rec = run_cell(args.arch, args.shape, args.multi_pod)
        raise SystemExit(0 if rec.get("ok") else 1)

    # local smoke: real optimization steps on the reduced config
    cfg = smoke_config(get_config(args.arch)) if args.local_smoke \
        else get_config(args.arch)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    latest = ckpt.latest_step(args.ckpt_dir)
    step0 = 0
    if latest is not None:
        state, step0 = ckpt.restore(
            args.ckpt_dir,
            jax.eval_shape(lambda: {"params": params, "opt": opt_state}))
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed at step {step0}")

    ts = jax.jit(make_train_step(cfg, microbatches=2))
    writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for step in range(step0, args.steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 64)), jnp.int32)
        params, opt_state, m = ts(params, opt_state,
                                  {"tokens": toks, "labels": toks})
        if step % 10 == 0:
            print(f"[train] step {step} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.0f}s)")
        if (step + 1) % args.ckpt_every == 0:
            writer.save(step + 1, {"params": params, "opt": opt_state})
    writer.close()


if __name__ == "__main__":
    main()
