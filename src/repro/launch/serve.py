"""Serving launcher: AAPA-autoscaled endpoint for any --arch.

    python -m repro.launch.serve --arch stablelm_1_6b --minutes 10
    python -m repro.launch.serve --arch stablelm_1_6b --dry-run  # decode
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--minutes", type=int, default=10)
    ap.add_argument("--policy", default="aapa",
                    help="any repro.scaling registry policy")
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        rec = run_cell(args.arch, args.shape, args.multi_pod)
        raise SystemExit(0 if rec.get("ok") else 1)

    import numpy as np
    import jax
    from repro.configs import get_config, smoke_config
    from repro.core import gbdt, pipeline
    from repro.models import model as M
    from repro.scaling import registry

    if args.policy not in ("reactive", *registry.available()):
        raise SystemExit(f"unknown --policy {args.policy!r}; "
                         f"available: {registry.available()}")

    cfg = smoke_config(get_config(args.arch))
    params = M.init(jax.random.PRNGKey(0), cfg)
    trained = pipeline.train_classifier(
        "aapaset_ci", gbdt.GBDTConfig(n_rounds=10, depth=3))
    print(f"[serve] {cfg.name} classifier on {trained.dataset_id} "
          f"acc={trained.test_acc:.3f}")

    import examples.serve_autoscale as demo
    rng = np.random.default_rng(0)
    rates = np.full(args.minutes, 120.0)
    rates[args.minutes // 2] = 2000.0
    s = demo.run(args.minutes, args.policy, trained, params, cfg, rates,
                 rng)
    print(f"[serve] {s}")


if __name__ == "__main__":
    main()
