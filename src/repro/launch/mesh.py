"""Production mesh construction (function, not module constant — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Tiny mesh for CPU integration tests (requires
    xla_force_host_platform_device_count >= n_data*n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
