"""Content-addressed tuning cards + the ``tuned:`` registry namespace.

A tuning card is addressed by the sha256 of its content key — the full
``TuneSpec`` plus the classifier id — on the exact scheme of
``repro.aapaset.manifest`` (canonical-JSON sha256, atomic staged
publish), like ``repro.evals.artifacts`` result cards. Re-running an
identical spec is a cache hit that skips the search entirely; bump
``repro.tuning.search.SCHEMA_VERSION`` whenever plant/metric/search math
changes the winner for the same key.

Layout under ``experiments/tuning/<name>-<hash12>/``:

* ``card.json`` — key, hash, policy, best point, default point + REI
  delta, the full search trace (per round) and per-candidate REI table,
  throughput meta.

The card hash is also the winner's durable address:
``registry.make(f"tuned:<policy>@<hash12>", cfg)`` resolves the card via
``resolve`` and rebuilds the tuned controller exactly (stored
hyperparameters applied over registry defaults — bit-identical to the
search-time build).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil

from repro.aapaset.manifest import hash_json, publish_dir, stage_dir

DEFAULT_ROOT = pathlib.Path("experiments/tuning")


def card_hash(key: dict) -> str:
    return hash_json(key)


def result_dir(name: str, key: dict,
               root: pathlib.Path | str = DEFAULT_ROOT) -> pathlib.Path:
    return pathlib.Path(root) / f"{name}-{card_hash(key)}"


def is_cached(name: str, key: dict,
              root: pathlib.Path | str = DEFAULT_ROOT) -> bool:
    return (result_dir(name, key, root) / "card.json").exists()


def save_run(spec, key: dict, result,
             root: pathlib.Path | str = DEFAULT_ROOT, *,
             replace: bool = False) -> dict:
    """Publish a TuneResult as card.json; returns the card.

    `replace=True` (a forced re-run) clears the existing artifact first —
    publish_dir's same-address race rule would otherwise keep the old
    copy and drop the fresh one."""
    out = result_dir(spec.name, key, root)
    tmp = stage_dir(out)
    card = {
        "schema": key.get("schema"),
        "key": key,
        "hash": card_hash(key),
        "policy": spec.policy,
        "spec": dataclasses.asdict(spec),
        "best": result.best,
        "best_rei": result.best_rei,
        "best_metrics": result.best_metrics,
        "default": result.default,
        "default_rei": result.default_rei,
        "rei_delta": result.best_rei - result.default_rei,
        "trace": result.trace,
        "table": result.table,
        "meta": result.meta,
    }
    with open(tmp / "card.json", "w") as f:
        json.dump(card, f, indent=1, default=float)
    if replace:
        shutil.rmtree(out, ignore_errors=True)
    publish_dir(tmp, out, "card.json")
    return card


def load_card(name: str, key: dict,
              root: pathlib.Path | str = DEFAULT_ROOT) -> dict:
    with open(result_dir(name, key, root) / "card.json") as f:
        return json.load(f)


def result_from_card(spec, card: dict):
    """Rebuild the TuneResult view of a cached card (cache-hit path of
    ``search.search``; `meta` keeps the original run's throughput)."""
    from repro.tuning.search import TuneResult
    return TuneResult(
        spec=spec, best=card["best"], best_rei=card["best_rei"],
        best_metrics=card["best_metrics"], default=card["default"],
        default_rei=card["default_rei"], table=card["table"],
        trace=card["trace"], meta=dict(card["meta"], cached=True))


def list_cards(root: pathlib.Path | str = DEFAULT_ROOT) -> list[dict]:
    """Every published tuning card under `root` (sorted by dir name)."""
    root = pathlib.Path(root)
    cards = []
    if root.is_dir():
        for p in sorted(root.glob("*/card.json")):
            with open(p) as f:
                cards.append(json.load(f))
    return cards


def resolve(ref: str,
            root: pathlib.Path | str | None = None) -> tuple[str, dict]:
    """``"<policy>@<hash12>"`` -> (policy, tuned hyperparameters).

    The hash addresses the card directory (`<name>-<hash12>`); the policy
    part is cross-checked against the card so a copy-pasted ref can't
    silently rebuild the wrong controller family. `root` defaults to
    `DEFAULT_ROOT` at call time (tests repoint the module attribute)."""
    if root is None:
        root = DEFAULT_ROOT
    if "@" not in ref:
        raise ValueError(f"tuned ref {ref!r} must look like "
                         "'<policy>@<hash12>'")
    policy, _, h = ref.partition("@")
    root = pathlib.Path(root)
    hits = sorted(root.glob(f"*-{h}/card.json")) if root.is_dir() else []
    if not hits:
        raise FileNotFoundError(
            f"no tuning card with hash {h!r} under {root} — run "
            "repro.tuning.search.search() first, or point root= at the "
            "experiments directory that holds it")
    with open(hits[0]) as f:
        card = json.load(f)
    if card.get("policy") != policy:
        raise ValueError(
            f"tuned ref {ref!r} names policy {policy!r} but card "
            f"{card.get('hash')} tuned {card.get('policy')!r}")
    # JSON round-trip keeps float64 repr exact and ints int; static keys
    # were canonicalized at proposal time, so this rebuilds bit-exactly.
    return policy, dict(card["best"])
