"""Policy auto-tuning at simulator speed (paper §IV.C, done properly):
search strategies over fused candidate lanes with content-addressed
tuning cards and a ``tuned:`` registry namespace.

    import repro.tuning as tuning
    run = tuning.search(tuning.spec("hpa_spike", policy="hpa"))
    ctrl = registry.make(f"tuned:hpa@{run.card['hash']}", cfg)

NB: the package re-exports the ``search`` *function*, so
``repro.tuning.search`` is the front door, not the submodule — use
``from repro.tuning import search as ...`` accordingly.
"""
from repro.tuning.search import (DEFAULT_SPACES, STRATEGIES, TuneResult,
                                 TuneRun, TuneSpec, build_rates,
                                 default_candidate, grid_candidates,
                                 make_evaluator, run_search, search,
                                 smoke_spec, spec)
from repro.tuning import artifacts

__all__ = ["DEFAULT_SPACES", "STRATEGIES", "TuneResult", "TuneRun",
           "TuneSpec", "artifacts", "build_rates", "default_candidate",
           "grid_candidates", "make_evaluator", "run_search", "search",
           "smoke_spec", "spec"]
