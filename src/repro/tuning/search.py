"""Policy auto-tuning at simulator speed: search strategies over fused
candidate lanes.

The paper fixes Table III's per-archetype scaling parameters by hand and
reports REI (§III.D) as the score that would let anyone do better. This
module does better: every candidate hyperparameter point is a fused lane
of ``repro.scaling.batch.make_grid_evaluator`` — pooled EpisodeMetrics +
REI accumulate *inside* the simulation scan, so scoring 10^3+ candidates
per dispatch never materializes per-minute output, and a full search
costs seconds on the O(P) batched simulator.

Three strategies, all driving the same fused evaluator with
deterministic seeded proposals (same spec + seed -> same candidate
sequence -> same winner):

* ``grid``        — the cartesian product over the search space.
* ``grid_refine`` — grid, then shrink the box around the incumbent and
                    re-grid, `rounds` times (constant candidate count
                    per round, so the compiled group body is reused).
* ``population``  — perturb-and-select over `generations`: elites
                    survive, the rest are gaussian perturbations of
                    elites with a decaying step.

A search space maps hyperparameter keys to either a ``(lo, hi)`` range
(policy `stackable` keys — traced f32 lanes) or a discrete choice list
(static keys like `stride_min` — one compile per static group):

    import repro.tuning as tuning
    run = tuning.search(tuning.spec(
        "hpa_spike", policy="hpa", scenario="archetype_pure",
        strategy="grid_refine"))
    run.result.best, run.result.best_rei, run.card["hash"]

``search`` is the content-addressed front door (``repro.tuning.
artifacts``): re-running an identical spec is a cache hit on the tuning
card, and the winner is rebuildable forever as
``registry.make(f"tuned:{policy}@{run.card['hash']}", cfg)``.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, NamedTuple, Sequence

import numpy as np

from repro.evals import metrics as EM
from repro.scaling import batch, registry, scenarios
from repro.sim.cluster import SimConfig

SCHEMA_VERSION = 1

#: Sensible default search boxes per policy family, spanning the paper
#: defaults (Table III / §IV.C): ranges for stackable keys, choices for
#: static ones.
DEFAULT_SPACES: dict[str, dict[str, Any]] = {
    "hpa": {"target": (0.4, 0.95), "cooldown_min": (0.5, 10.0),
            "tolerance": (0.02, 0.3)},
    "predictive": {"target": (0.4, 0.95), "cooldown_min": (0.5, 10.0)},
    "kpa": {"panic_threshold": (1.2, 4.0)},
    "hybrid": {"guard_target": (0.6, 0.95), "max_down_frac": (0.1, 0.6)},
    "aapa": {"stride_min": [5, 10, 20], "horizon_min": [5, 15, 30]},
}

STRATEGIES = ("grid", "grid_refine", "population")


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """One named tuning run. Every field is part of the content key."""
    name: str
    policy: str
    space: tuple[tuple[str, tuple], ...]   # (key, ("range", lo, hi) |
    #                                        (key, ("choice", v, ...)))
    strategy: str = "grid_refine"
    scenario: str = "archetype_pure"
    scenario_kw: tuple[tuple[str, Any], ...] = ()
    n_workloads: int = 4
    minutes: int = 240
    seed: int = 0
    fixed: tuple[tuple[str, Any], ...] = ()
    sim: tuple[tuple[str, Any], ...] = ()
    bins: int = EM.DEFAULT_BINS
    # strategy knobs (all hashed; unused ones are inert for a strategy)
    points: int = 5          # grid points per range dimension
    rounds: int = 4          # grid_refine rounds
    shrink: float = 0.5      # box shrink per refine round / sigma decay
    population: int = 64     # population size
    generations: int = 8
    elite_frac: float = 0.25
    sigma0: float = 0.25     # initial perturbation (fraction of span)

    def sim_config(self) -> SimConfig:
        return SimConfig(**dict(self.sim))

    def content_key(self) -> dict:
        return {"schema": SCHEMA_VERSION, "name": self.name,
                "policy": self.policy,
                "space": [[k, list(v)] for k, v in self.space],
                "strategy": self.strategy, "scenario": self.scenario,
                "scenario_kw": dict(self.scenario_kw),
                "n_workloads": self.n_workloads, "minutes": self.minutes,
                "seed": self.seed, "fixed": dict(self.fixed),
                "sim": dict(self.sim), "bins": self.bins,
                "points": self.points, "rounds": self.rounds,
                "shrink": self.shrink, "population": self.population,
                "generations": self.generations,
                "elite_frac": self.elite_frac, "sigma0": self.sigma0}


def _norm_space(policy: str, space: dict | None) -> tuple:
    """Normalize a {key: (lo, hi) | [choices] | tagged tuple} space:
    stackable keys become ("range", lo, hi), static keys
    ("choice", ...). Keys are validated against the policy's accepted
    hyperparameters up front."""
    sp = registry.spec(policy)
    if space is None:
        space = DEFAULT_SPACES.get(policy)
        if space is None:
            raise KeyError(f"no default search space for {policy!r}; "
                           f"pass space=; defaults exist for "
                           f"{sorted(DEFAULT_SPACES)}")
    bad = set(space) - set(sp.defaults)
    if bad:
        raise TypeError(f"policy {policy!r} has no hyperparameters "
                        f"{sorted(bad)} (search space); "
                        f"accepts {sorted(sp.defaults)}")
    norm = []
    for key in sorted(space):
        val = space[key]
        if isinstance(val, (tuple, list)) and len(val) and \
                val[0] in ("range", "choice"):
            tag, rest = val[0], tuple(val[1:])
        elif key in sp.stackable and isinstance(val, (tuple, list)) \
                and len(val) == 2:
            tag, rest = "range", (float(val[0]), float(val[1]))
        else:
            tag, rest = "choice", tuple(val)
        if tag == "range":
            if key not in sp.stackable:
                raise TypeError(
                    f"{key!r} is not stackable for {policy!r} — a "
                    f"continuous range needs traced lanes; give a "
                    f"discrete choice list instead "
                    f"(stackable: {sorted(sp.stackable)})")
            lo, hi = float(rest[0]), float(rest[1])
            if not lo < hi:
                raise ValueError(f"empty range for {key!r}: ({lo}, {hi})")
            norm.append((key, ("range", lo, hi)))
        else:
            norm.append((key, ("choice",)
                         + tuple(batch._canon_static(v) for v in rest)))
    return tuple(norm)


def spec(name: str, *, policy: str, space: dict | None = None,
         scenario_kw: dict | None = None, fixed: dict | None = None,
         sim: dict | None = None, **kw) -> TuneSpec:
    """Normalizing constructor (mirrors ``evals.matrix.spec``)."""
    if kw.get("strategy", "grid_refine") not in STRATEGIES:
        raise ValueError(f"unknown strategy {kw['strategy']!r}; "
                         f"one of {STRATEGIES}")
    return TuneSpec(
        name=name, policy=policy, space=_norm_space(policy, space),
        scenario_kw=tuple(sorted((scenario_kw or {}).items())),
        fixed=tuple(sorted((fixed or {}).items())),
        sim=tuple(sorted((sim or {}).items())), **kw)


def smoke_spec() -> TuneSpec:
    """The CI tier-1 smoke search: a seconds-scale hpa grid, one static
    group, on a short SPIKE scenario."""
    return spec("ci_tuning_smoke", policy="hpa", strategy="grid",
                space={"target": (0.45, 0.9), "cooldown_min": (1.0, 8.0)},
                points=4, n_workloads=2, minutes=120)


# ----------------------------------------------------------- proposals ----
def _ranges(space) -> list[tuple[str, float, float]]:
    return [(k, v[1], v[2]) for k, v in space if v[0] == "range"]


def _choices(space) -> list[tuple[str, tuple]]:
    return [(k, v[1:]) for k, v in space if v[0] == "choice"]


def grid_candidates(space, points: int,
                    box: dict[str, tuple[float, float]] | None = None
                    ) -> list[dict]:
    """Cartesian product: `points` per range dimension (over `box` when
    refining) x every choice value. Deterministic ordering."""
    axes, keys = [], []
    for k, lo, hi in _ranges(space):
        if box is not None:
            lo, hi = box[k]
        keys.append(k)
        axes.append([float(x) for x in np.linspace(lo, hi, points)])
    for k, vals in _choices(space):
        keys.append(k)
        axes.append(list(vals))
    return [dict(zip(keys, combo)) for combo in itertools.product(*axes)]


def default_candidate(spec_: TuneSpec) -> dict:
    """The paper-default point: registry defaults restricted to the
    searched keys (what the search must beat)."""
    defaults = registry.spec(spec_.policy).defaults
    return {k: batch._canon_static(defaults[k]) for k, _ in spec_.space}


def _sample(space, rng: np.random.Generator) -> dict:
    cand = {k: float(rng.uniform(lo, hi)) for k, lo, hi in _ranges(space)}
    for k, vals in _choices(space):
        cand[k] = vals[int(rng.integers(len(vals)))]
    return cand


def _perturb(parent: dict, space, sigma: float,
             rng: np.random.Generator) -> dict:
    child = dict(parent)
    for k, lo, hi in _ranges(space):
        child[k] = float(np.clip(parent[k]
                                 + rng.normal(0.0, sigma * (hi - lo)),
                                 lo, hi))
    for k, vals in _choices(space):
        if len(vals) > 1 and rng.random() < 0.2:
            child[k] = vals[int(rng.integers(len(vals)))]
    return child


# ------------------------------------------------------------ execution ----
class TuneResult(NamedTuple):
    spec: TuneSpec
    best: dict               # winning hyperparameters
    best_rei: float
    best_metrics: dict       # pooled EpisodeMetrics of the winner
    default: dict            # the paper-default point searched against
    default_rei: float
    table: list[dict]        # every evaluated candidate: {**params, rei}
    trace: list[dict]        # per-round search trajectory
    meta: dict               # throughput + accounting


def build_rates(spec_: TuneSpec) -> np.ndarray:
    sc = scenarios.get(spec_.scenario, n_workloads=spec_.n_workloads,
                       minutes=spec_.minutes, seed=spec_.seed,
                       cfg=spec_.sim_config(), **dict(spec_.scenario_kw))
    return np.asarray(sc.rates, np.float32)


def make_evaluator(spec_: TuneSpec, classify=None):
    """(candidates, rates) -> (EpisodeMetrics [G], rei [G] np.ndarray),
    fused; the compiled group body is shared across rounds."""
    ev = batch.make_grid_evaluator(spec_.policy, spec_.sim_config(),
                                   classify=classify, bins=spec_.bins,
                                   **dict(spec_.fixed))

    def evaluate(cands: Sequence[dict], rates):
        met, rb = ev(list(cands), rates)
        return met, np.asarray(rb.rei)

    evaluate._cache_size = ev._cache_size
    return evaluate


def _round_record(i: int, cands, scores: np.ndarray, extra=None) -> dict:
    k = int(np.argmax(scores))
    rec = {"round": i, "n_candidates": len(cands),
           "best_rei": float(scores[k]), "best": dict(cands[k]),
           "mean_rei": float(scores.mean())}
    if extra:
        rec.update(extra)
    return rec


def run_search(spec_: TuneSpec, classify=None) -> TuneResult:
    """Execute the search (no caching — ``search`` is the front door)."""
    rates = build_rates(spec_)
    evaluate = make_evaluator(spec_, classify)
    rng = np.random.default_rng(spec_.seed)
    t0 = time.perf_counter()

    table: list[dict] = []
    trace: list[dict] = []
    best: dict | None = None
    best_rei = -np.inf
    best_idx_metrics = None

    def score_round(i, cands, extra=None):
        nonlocal best, best_rei, best_idx_metrics
        met, scores = evaluate(cands, rates)
        for c, s in zip(cands, scores):
            table.append({**c, "rei": float(s)})
        k = int(np.argmax(scores))
        if float(scores[k]) > best_rei:
            best, best_rei = dict(cands[k]), float(scores[k])
            best_idx_metrics = {f: float(np.asarray(getattr(met, f))[k])
                                for f in EM.EpisodeMetrics._fields}
        trace.append(_round_record(i, cands, scores, extra))
        return scores

    if spec_.strategy == "grid":
        score_round(0, grid_candidates(spec_.space, spec_.points))
    elif spec_.strategy == "grid_refine":
        box = {k: (lo, hi) for k, lo, hi in _ranges(spec_.space)}
        full = {k: (lo, hi) for k, lo, hi in _ranges(spec_.space)}
        for r in range(spec_.rounds):
            cands = grid_candidates(spec_.space, spec_.points, box=box)
            score_round(r, cands,
                        {"box": {k: list(v) for k, v in box.items()}})
            for k, (flo, fhi) in full.items():     # shrink around incumbent
                half = (box[k][1] - box[k][0]) * spec_.shrink / 2.0
                c = float(np.clip(best[k], flo + half, fhi - half)) \
                    if 2 * half <= fhi - flo else (flo + fhi) / 2.0
                box[k] = (c - half, c + half)
    elif spec_.strategy == "population":
        pop = [_sample(spec_.space, rng) for _ in range(spec_.population)]
        n_elite = max(1, int(spec_.elite_frac * spec_.population))
        for g in range(spec_.generations):
            sigma = spec_.sigma0 * (spec_.shrink ** g)
            scores = score_round(g, pop, {"sigma": sigma})
            elite_ix = np.argsort(-scores)[:n_elite]
            elites = [dict(pop[int(i)]) for i in elite_ix]
            pop = elites + [
                _perturb(elites[i % n_elite], spec_.space, sigma, rng)
                for i in range(spec_.population - n_elite)]
    else:                                # pragma: no cover - spec() guards
        raise ValueError(f"unknown strategy {spec_.strategy!r}")

    default = default_candidate(spec_)
    _, dscore = evaluate([default], rates)
    wall = time.perf_counter() - t0
    n = len(table)
    return TuneResult(
        spec=spec_, best=best, best_rei=best_rei,
        best_metrics=best_idx_metrics, default=default,
        default_rei=float(dscore[0]), table=table, trace=trace,
        meta={"wall_s": wall, "n_candidates": n,
              "candidates_per_sec": n / max(wall, 1e-9),
              "compiles": evaluate._cache_size(),
              "workloads": spec_.n_workloads, "minutes": spec_.minutes,
              "rei_delta": best_rei - float(dscore[0])})


class TuneRun(NamedTuple):
    spec: TuneSpec
    result: TuneResult
    card: dict
    cached: bool


def search(spec_: TuneSpec, *, classify=None, classifier_id: str = "",
           root=None, force: bool = False) -> TuneRun:
    """The content-addressed front door: run the search, publish the
    tuning card, or return the cached one for an identical spec.

    `classifier_id` must name the classifier whenever `classify` is
    passed (the callable cannot be hashed)."""
    from repro.tuning import artifacts
    if classify is not None and not classifier_id:
        raise ValueError("pass classifier_id= to content-address a "
                         "search with a custom classifier")
    key = dict(spec_.content_key(),
               classifier=classifier_id or "default_classify")
    root = artifacts.DEFAULT_ROOT if root is None else root
    if not force and artifacts.is_cached(spec_.name, key, root):
        card = artifacts.load_card(spec_.name, key, root)
        return TuneRun(spec_, artifacts.result_from_card(spec_, card),
                       card, True)
    result = run_search(spec_, classify)
    card = artifacts.save_run(spec_, key, result, root, replace=force)
    return TuneRun(spec_, result, card, False)
