"""Content-addressed observability cards: traced runs you can point at.

`capture_matrix` re-runs an evaluation matrix with telemetry on and
publishes what the autoscaler *did* — not just how it scored — under
``experiments/obs/<name>-<hash12>/`` using the same canonical-JSON
sha256 + staged-atomic-publish scheme as the evals result cards:

* ``card.json``    — key, axes, per-lane blame table, per-archetype
  blame split, and the per-cause totals (their sum equals the pooled
  violation total — pinned by tests/test_obs.py).
* ``trace.npz``    — every ControlTrace array, decisions keyed
  ``dec.<field>`` ([S, Z, M, H, F, P, K]) and minutes ``min.<field>``
  ([S, Z, M, F, P, K]).
* ``timeline.md``  — rendered decision timeline of the worst lane (most
  violated requests), blame-annotated.

The content key extends the matrix key with the obs schema version and
`trace_lanes`, so an obs card never collides with a result card and a
capture at different sampling is a different address. Telemetry rides
the same compiled runner as the scored run (`matrix.make_runner` with
``telemetry=True``), so the card's blame is attributed against exactly
the decisions the evaluation executed.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
from typing import NamedTuple

import numpy as np

from repro.aapaset.manifest import hash_json, publish_dir, stage_dir
from repro.evals import matrix
from repro.obs import attribute as AT
from repro.obs.trace import ControlTrace, DecisionRecord, MinuteTrace, lane

__all__ = ["OBS_SCHEMA", "DEFAULT_ROOT", "ObsCapture", "obs_key",
           "capture_dir", "is_cached", "capture_matrix", "load_capture"]

OBS_SCHEMA = 1
DEFAULT_ROOT = pathlib.Path("experiments/obs")


class ObsCapture(NamedTuple):
    spec: matrix.MatrixSpec
    trace: ControlTrace      # numpy leaves
    blames: dict             # lane label -> Blame
    card: dict
    cached: bool


def obs_key(spec_: matrix.MatrixSpec, classifier_id: str = "",
            trace_lanes: int | None = None) -> dict:
    return dict(spec_.content_key(), obs_schema=OBS_SCHEMA,
                classifier=classifier_id or "default_classify",
                trace_lanes=trace_lanes)


def capture_dir(name: str, key: dict,
                root: pathlib.Path | str = DEFAULT_ROOT) -> pathlib.Path:
    return pathlib.Path(root) / f"{name}-{hash_json(key)}"


def is_cached(name: str, key: dict,
              root: pathlib.Path | str = DEFAULT_ROOT) -> bool:
    return (capture_dir(name, key, root) / "card.json").exists()


def _lane_labels(spec_: matrix.MatrixSpec, K: int):
    """(label, (s, z), (f, p, k)) per traced lane, matrix-order."""
    scs = spec_.scenario_names()
    for s, sc in enumerate(scs):
        for z, seed in enumerate(spec_.seeds):
            for f, fc in enumerate(spec_.forecasters):
                for p, pol in enumerate(spec_.policies):
                    for k in range(K):
                        label = f"{sc}/z{seed}/{pol}"
                        if len(spec_.forecasters) > 1:
                            label = f"{sc}/z{seed}/{pol}[{fc}]"
                        yield f"{label}/w{k}", (s, z), (f, p, k)


def _blame_all(spec_: matrix.MatrixSpec, ct: ControlTrace, cfg):
    blames, arch_rows = {}, {}
    K = ct.minutes.rate.shape[-1]
    for label, pre, post in _lane_labels(spec_, K):
        ln = lane(ct, pre, post)
        b = AT.attribute(ln, cfg)
        blames[label] = b
        AT.archetype_counts(ln, b, into=arch_rows)
    return blames, arch_rows


def capture_matrix(spec_: matrix.MatrixSpec, classify=None, *,
                   classifier_id: str = "",
                   trace_lanes: int | None = None,
                   root: pathlib.Path | str = DEFAULT_ROOT,
                   force: bool = False) -> ObsCapture:
    """The obs front door: traced matrix run -> published obs card."""
    import jax

    if classify is not None and not classifier_id:
        raise ValueError("pass classifier_id= to content-address a "
                         "capture with a custom classifier")
    key = obs_key(spec_, classifier_id, trace_lanes)
    if not force and is_cached(spec_.name, key, root):
        return load_capture(spec_.name, key, root)

    cfg = spec_.sim_config()
    run = matrix.make_runner(spec_, classify, telemetry=True,
                             trace_lanes=trace_lanes)
    rates = matrix.build_rates(spec_)
    _, _, ct = jax.block_until_ready(run(rates))
    ct = jax.tree.map(np.asarray, ct)

    blames, arch_rows = _blame_all(spec_, ct, cfg)
    totals = {c: sum(b.counts[c] for b in blames.values())
              for c in AT.CAUSES}
    worst = max(blames, key=lambda lb: blames[lb].total)
    wl = next((pre, post) for lb, pre, post
              in _lane_labels(spec_, ct.minutes.rate.shape[-1])
              if lb == worst)
    worst_ln = lane(ct, *wl)
    timeline = (f"# Decision timeline: {worst}\n\n"
                + AT.timeline(worst_ln, blames[worst]))

    card = {
        "obs_schema": OBS_SCHEMA, "key": key, "hash": hash_json(key),
        "spec": dataclasses.asdict(spec_),
        "trace_lanes": trace_lanes,
        "blame_totals": totals,
        "violations_total": sum(totals.values()),
        "worst_lane": worst,
        "tables": {"blame": AT.blame_table(blames),
                   "by_archetype": AT.archetype_table(arch_rows)},
    }
    out = capture_dir(spec_.name, key, root)
    tmp = stage_dir(out)
    np.savez_compressed(tmp / "trace.npz", **_trace_arrays(ct))
    with open(tmp / "timeline.md", "w") as f:
        f.write(timeline + "\n")
    with open(tmp / "card.json", "w") as f:
        json.dump(card, f, indent=1, default=float)
    if force:
        shutil.rmtree(out, ignore_errors=True)
    publish_dir(tmp, out, "card.json")
    return ObsCapture(spec_, ct, blames, card, False)


def _trace_arrays(ct: ControlTrace) -> dict[str, np.ndarray]:
    arrays = {}
    for prefix, tree in (("dec", ct.decisions), ("min", ct.minutes)):
        for field, arr in tree._asdict().items():
            arrays[f"{prefix}.{field}"] = np.asarray(arr)
    return arrays


def load_capture(name: str, key: dict,
                 root: pathlib.Path | str = DEFAULT_ROOT) -> ObsCapture:
    out = capture_dir(name, key, root)
    with open(out / "card.json") as f:
        card = json.load(f)
    with np.load(out / "trace.npz") as z:
        fields = {k: z[k] for k in z.files}
    ct = ControlTrace(
        decisions=DecisionRecord(**{f: fields[f"dec.{f}"]
                                    for f in DecisionRecord._fields}),
        minutes=MinuteTrace(**{f: fields[f"min.{f}"]
                               for f in MinuteTrace._fields}))
    spec_ = _spec_from_card(card)
    blames, _ = _blame_all(spec_, ct, spec_.sim_config())
    return ObsCapture(spec_, ct, blames, card, True)


def _spec_from_card(card: dict) -> matrix.MatrixSpec:
    d = dict(card["spec"])
    d["policies"] = tuple(d["policies"])
    d["forecasters"] = tuple(d["forecasters"])
    d["seeds"] = tuple(d["seeds"])
    d["scenarios"] = tuple((n, tuple((k, v) for k, v in kw))
                           for n, kw in d["scenarios"])
    d["sim"] = tuple((k, v) for k, v in d["sim"])
    return matrix.MatrixSpec(**d)
