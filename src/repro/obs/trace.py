"""Decision-telemetry schema: what a control decision looked like.

One `DecisionRecord` per control-period head, captured *inside* the
compiled simulation scan (``repro.sim.cluster`` single lane,
``repro.scaling.batch`` fused P x W lanes) and — with the very same
field meanings — appended eagerly by the live-engine adapter
(``repro.scaling.adapter.EngineAutoscaler``), so a sim trace and an
engine trace of the same policy are directly diffable.

The schema is flat f32 on purpose: every field stacks into scan ys
without reshaping, NaN marks "this policy has no such signal" (hpa has
no forecast, only hybrid has a guard floor), and the NumPy post-hoc
consumers (``repro.obs.attribute``, the obs cards) never need a sidecar
describing which policy produced which lane.

`ControlTrace` bundles the per-head decisions with the per-minute plant
outcomes (arrivals, served, violated) of the same lane — everything the
blame walk in ``repro.obs.attribute`` needs, self-contained.

This module depends only on jax/numpy so the sim core and the scaling
layer can import it without cycles; the heavier consumers live in
``repro.obs.attribute`` / ``repro.obs.artifacts`` (lazy in the package
``__init__``).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


class ExplainOut(NamedTuple):
    """A controller's self-report of the signals behind one decision.
    Produced by `Controller.explain` (optional hook, same (state, obs)
    inputs as `decide` on the PRE-decide state); NaN where a policy has
    no such signal."""
    fc_point: jax.Array      # forecast point (arrivals/min, horizon peak)
    fc_lo: jax.Array         # forecast interval bounds
    fc_hi: jax.Array
    confidence: jax.Array    # effective confidence fed to Algorithm 1
    archetype: jax.Array     # f32 archetype id (0..3), NaN when untyped
    guard_floor: jax.Array   # hybrid's reactive floor, NaN otherwise


class DecisionRecord(NamedTuple):
    """One control decision, fully accounted: observation -> controller
    signals -> raw desired -> clip/cooldown outcome. All fields f32 and
    broadcast to a common lane shape."""
    minute: jax.Array          # global minute index of the decision
    sec: jax.Array             # second-of-minute of the block head
    ready: jax.Array           # ready replicas at the decision
    total: jax.Array           # ready + starting (what desired compares to)
    queue: jax.Array
    util_ema: jax.Array
    rate_rps: jax.Array        # arrival rate the controller saw
    fc_point: jax.Array        # ExplainOut passthrough (NaN when absent)
    fc_lo: jax.Array
    fc_hi: jax.Array
    confidence: jax.Array
    archetype: jax.Array
    guard_floor: jax.Array
    desired_raw: jax.Array     # decide() output before the max_replicas clip
    desired: jax.Array         # after the clip (what apply_decision saw)
    target: jax.Array          # total + add - remove (what the plant got)
    cooldown_req: jax.Array    # cooldown the controller requested (s)
    cooldown_before: jax.Array # limiter cooldown remaining at the decision
    scale_up: jax.Array        # 1.0 when the action fired
    scale_down: jax.Array
    cooldown_blocked: jax.Array  # wanted a scale-down, cooldown held it
    capacity_capped: jax.Array   # desired_raw exceeded max_replicas


class MinuteTrace(NamedTuple):
    """Per-minute plant outcomes of the traced lane (the blame walk's
    ground truth about what actually happened)."""
    rate: jax.Array          # arrivals that minute
    served: jax.Array
    violated: jax.Array
    queue_end: jax.Array
    ready_mean: jax.Array


class ControlTrace(NamedTuple):
    """decisions: DecisionRecord leaves [..., M, H, ...lane axes];
    minutes: MinuteTrace leaves [..., M, ...lane axes]. The exact axis
    layout depends on the producer (see each simulate/runner docstring);
    `lane()` below slices out one lane either way."""
    decisions: DecisionRecord
    minutes: MinuteTrace


def explain_nan(shape: tuple = ()) -> ExplainOut:
    """The no-signal ExplainOut for policies without an explain hook."""
    nan = jnp.full(shape, jnp.nan, jnp.float32)
    return ExplainOut(fc_point=nan, fc_lo=nan, fc_hi=nan, confidence=nan,
                      archetype=nan, guard_floor=nan)


def record(cfg, *, minute_idx, sec, ready, total, queue, util_ema,
           rate_rps, exp: ExplainOut, desired_raw, desired, cooldown_req,
           cooldown_before, act) -> DecisionRecord:
    """Assemble one DecisionRecord from the decision-site values; every
    field is cast to f32 and broadcast to `desired`'s lane shape."""
    shape = jnp.shape(desired)

    def f(x):
        return jnp.broadcast_to(jnp.asarray(x, jnp.float32), shape)

    return DecisionRecord(
        minute=f(minute_idx), sec=f(sec), ready=f(ready), total=f(total),
        queue=f(queue), util_ema=f(util_ema), rate_rps=f(rate_rps),
        fc_point=f(exp.fc_point), fc_lo=f(exp.fc_lo), fc_hi=f(exp.fc_hi),
        confidence=f(exp.confidence), archetype=f(exp.archetype),
        guard_floor=f(exp.guard_floor),
        desired_raw=f(desired_raw), desired=f(desired),
        target=f(total + act.add - act.remove),
        cooldown_req=f(cooldown_req), cooldown_before=f(cooldown_before),
        scale_up=f(act.scale_up), scale_down=f(act.scale_down),
        cooldown_blocked=f((desired < total - 0.5)
                           & (cooldown_before > 0.0)),
        capacity_capped=f(desired_raw > cfg.max_replicas))


def head_schedule(cfg) -> list[int]:
    """Seconds-of-minute of the control-period block heads — the H axis
    of every in-scan trace, matching the blocked scan's schedule
    (`sec % control_interval_sec == 0`)."""
    ci = max(min(int(cfg.control_interval_sec), 60), 1)
    n_full = 60 // ci
    heads = [k * ci for k in range(n_full)]
    if 60 - n_full * ci:
        heads.append(n_full * ci)
    return heads


def sample_lanes(W: int, k: int | None) -> np.ndarray | None:
    """Deterministic evenly-spaced lane sample: the static index set that
    bounds fleet-scale capture to k of W lanes. None/k >= W keeps all."""
    if k is None or k >= W:
        return None
    if k <= 0:
        raise ValueError(f"trace_lanes must be positive, got {k}")
    return np.unique(np.linspace(0, W - 1, k).round().astype(np.int64))


def stack_records(records: list[DecisionRecord]) -> DecisionRecord:
    """Host-side: a list of scalar DecisionRecords (the adapter's log)
    -> one DecisionRecord of [N] numpy arrays."""
    if not records:
        return DecisionRecord(*(np.zeros((0,), np.float32)
                                for _ in DecisionRecord._fields))
    return DecisionRecord(*(
        np.asarray([np.float32(getattr(r, f)) for r in records])
        for f in DecisionRecord._fields))


def to_numpy(ct: ControlTrace) -> ControlTrace:
    return jax.tree.map(np.asarray, ct)


def lane(ct: ControlTrace, pre: tuple = (), post: tuple = ()
         ) -> ControlTrace:
    """Slice one lane out of a batched ControlTrace: `pre` indexes the
    axes BEFORE the time axes ([M, H] / [M]), `post` the lane axes after
    them. E.g. matrix traces [S, Z, M, H, F, P, K] -> lane(ct, (s, z),
    (f, p, k)); single-lane simulate traces need no indices at all."""
    dec = jax.tree.map(
        lambda a: np.asarray(a)[pre + (slice(None), slice(None)) + post],
        ct.decisions)
    mnt = jax.tree.map(
        lambda a: np.asarray(a)[pre + (slice(None),) + post], ct.minutes)
    return ControlTrace(decisions=dec, minutes=mnt)
