"""SLO blame attribution: walk violations back to the decision at fault.

A violated minute is an *outcome*; the decision that caused it happened
earlier — capacity ordered at the responsible head only becomes ready
``startup_sec`` later. `attribute` walks each violated minute of a
single-lane `ControlTrace` back through that cold-start window to the
last decision whose scale-up could still have landed in time, then
classifies the minute down a cascade of mutually-exclusive causes:

* ``capacity_capped`` — the controller asked for more than
  ``max_replicas``; no decision could have satisfied demand.
* ``cooldown_suppressed`` — a scale-down executed inside the cold-start
  window dropped capacity below what the minute needed (downs remove
  ready replicas immediately). Had the cooldown suppressed it, the
  violation would not have happened: the cooldown is the knob at fault.
* ``limiter_clamped`` — the decision wanted enough but the executed
  target was clamped below it. In-sim `apply_decision` never lowers a
  scale-up, so this bucket fires only on engine traces where an external
  limiter sits between desired and target.
* ``confidence_downscale`` — the forecast alone implied enough capacity,
  but the decision came out below need: the uncertainty-weighted blend
  (Algorithm 1's confidence term) scaled the forecast down past the
  demand line.
* ``under_forecast`` — everything else: the forecast (or reactive
  signal) under-called demand, including reacting too late for the
  startup pipeline to matter.

Every violated minute lands in exactly one bucket, so the per-cause
violation counts sum to the pooled violation total by construction —
pinned by tests/test_obs.py against `EpisodeMetrics`.

Host-side NumPy on purpose: traces are short ([M, H] per lane) and the
cascade is branch-heavy; keeping it out of jit keeps the capture path's
compiled program telemetry-gated and this logic trivially editable.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.archetypes import ARCHETYPE_NAMES
from repro.obs.trace import ControlTrace

__all__ = ["CAUSES", "Blame", "need_replicas", "attribute",
           "blame_table", "archetype_counts", "archetype_table",
           "timeline"]

CAUSES = ("capacity_capped", "cooldown_suppressed", "limiter_clamped",
          "confidence_downscale", "under_forecast")


class Blame(NamedTuple):
    """Per-minute verdicts plus the per-cause violation totals."""
    cause: np.ndarray        # [M] int8 index into CAUSES, -1 = no violation
    responsible: np.ndarray  # [M] int64 flat decision index (-1 likewise)
    violated: np.ndarray     # [M] violated requests per minute
    need: np.ndarray         # [M] replicas the minute needed
    counts: dict             # cause name -> violated-request total
    total: float             # sum of counts == sum of violated


def need_replicas(rate_per_min, cfg) -> np.ndarray:
    """Replicas needed to serve `rate_per_min` within the SLO.

    Inverts the fluid M/D/1-style congestion model the plant runs:
    response ~= service / (1 - u) <= slo gives the admissible
    utilization u_slo = 1 - service/slo, so a replica absorbs
    rps_per_replica * u_slo req/s before the queue pushes past the SLO.
    """
    u_slo = max(1.0 - cfg.service_sec / cfg.slo_sec, 0.05)
    rps = np.maximum(np.asarray(rate_per_min, np.float64), 0.0) / 60.0
    return np.ceil(rps / (cfg.rps_per_replica * u_slo))


def attribute(ct: ControlTrace, cfg) -> Blame:
    """Blame every violated minute of ONE lane ([M, H] decisions)."""
    d, mt = ct.decisions, ct.minutes
    M = np.asarray(d.minute).shape[0]
    flat = {f: np.asarray(getattr(d, f), np.float64).reshape(-1)
            for f in d._fields}
    abs_sec = flat["minute"] * 60.0 + flat["sec"]       # increasing [M*H]
    violated = np.asarray(mt.violated, np.float64)
    need = need_replicas(np.asarray(mt.rate, np.float64), cfg)
    fc_need = need_replicas(flat["fc_point"], cfg)      # NaN -> NaN-safe ops

    cause = np.full(M, -1, np.int8)
    resp = np.full(M, -1, np.int64)
    counts = {c: 0.0 for c in CAUSES}
    for m in np.nonzero(violated > 0)[0]:
        # Last decision whose ordered capacity was live by minute m.
        ds = int(np.searchsorted(abs_sec + cfg.startup_sec, 60.0 * m,
                                 side="right")) - 1
        ds = max(ds, 0)
        resp[m] = ds
        if flat["capacity_capped"][ds] > 0.5:
            c = "capacity_capped"
        elif _recent_down_below(flat, abs_sec, ds, m, need[m]):
            c = "cooldown_suppressed"
        elif flat["target"][ds] < flat["desired"][ds] - 0.5:
            c = "limiter_clamped"
        elif (np.isfinite(fc_need[ds]) and fc_need[ds] >= need[m]
              and flat["desired_raw"][ds] < need[m] - 0.5):
            c = "confidence_downscale"
        else:
            c = "under_forecast"
        cause[m] = CAUSES.index(c)
        counts[c] += float(violated[m])
    return Blame(cause=cause, responsible=resp, violated=violated,
                 need=need, counts=counts, total=float(violated.sum()))


def _recent_down_below(flat, abs_sec, ds, m, need_m) -> bool:
    """Did a scale-down executed in (responsible head, end of minute m]
    take the plant's target below the minute's need?"""
    lo, hi = ds + 1, int(np.searchsorted(abs_sec, 60.0 * (m + 1)))
    if lo >= hi:
        return False
    down = flat["scale_down"][lo:hi] > 0.5
    return bool(np.any(down & (flat["target"][lo:hi] < need_m - 0.5)))


def _fmt(x: float) -> str:
    return "n/a" if not np.isfinite(x) else f"{x:.1f}"


def blame_table(blames: dict) -> str:
    """{label: Blame} -> markdown table, one row per traced lane."""
    head = ["lane", "violated"] + list(CAUSES)
    lines = ["| " + " | ".join(head) + " |",
             "|" + "---|" * len(head)]
    for label, b in blames.items():
        row = [label, f"{b.total:.0f}"]
        row += [f"{b.counts[c]:.0f}" for c in CAUSES]
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def archetype_counts(ct: ControlTrace, blame: Blame,
                     into: dict | None = None) -> dict:
    """Per-archetype blame split of ONE lane, keyed by the archetype the
    controller reported at the responsible decision (aapa lanes; NaN
    archetypes — untyped policies — pool under 'untyped'). Pass `into`
    to merge several lanes into one table."""
    arch = np.asarray(ct.decisions.archetype, np.float64).reshape(-1)
    rows = {} if into is None else into
    for m in np.nonzero(blame.cause >= 0)[0]:
        a = arch[blame.responsible[m]]
        name = (ARCHETYPE_NAMES[int(a)] if np.isfinite(a)
                and 0 <= int(a) < len(ARCHETYPE_NAMES) else "untyped")
        row = rows.setdefault(name, {c: 0.0 for c in CAUSES})
        row[CAUSES[blame.cause[m]]] += float(blame.violated[m])
    return rows


def archetype_table(rows: dict) -> str:
    """Render `archetype_counts` rows as a markdown table."""
    head = ["archetype", "violated"] + list(CAUSES)
    lines = ["| " + " | ".join(head) + " |",
             "|" + "---|" * len(head)]
    for name in sorted(rows):
        row = rows[name]
        cells = [name, f"{sum(row.values()):.0f}"]
        cells += [f"{row[c]:.0f}" for c in CAUSES]
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)


def timeline(ct: ControlTrace, blame: Blame | None = None,
             max_rows: int = 64) -> str:
    """Markdown decision timeline of ONE lane: what each head saw and
    did. With `blame`, violated minutes are annotated with their cause;
    rows prioritize blamed minutes when the trace exceeds `max_rows`."""
    d = ct.decisions
    M, H = np.asarray(d.minute).shape
    f = {k: np.asarray(getattr(d, k), np.float64) for k in d._fields}
    flag_minutes = (set() if blame is None
                    else set(np.nonzero(blame.cause >= 0)[0].tolist()))
    minutes = list(range(M))
    if len(minutes) * H > max_rows:
        rest = [m for m in minutes if m not in flag_minutes]
        keep = max(max_rows // H - len(flag_minutes), 0)
        step = max(len(rest) // keep, 1) if keep else len(rest) + 1
        minutes = sorted(flag_minutes | set(rest[::step]))
    head = ["t", "rate/s", "fc/min", "conf", "ready", "desired",
            "target", "flags", "cause"]
    lines = ["| " + " | ".join(head) + " |", "|" + "---|" * len(head)]
    for m in minutes:
        for h in range(H):
            flags = []
            if f["scale_up"][m, h] > 0.5:
                flags.append("up")
            if f["scale_down"][m, h] > 0.5:
                flags.append("down")
            if f["cooldown_blocked"][m, h] > 0.5:
                flags.append("cooldown")
            if f["capacity_capped"][m, h] > 0.5:
                flags.append("capped")
            c = ("" if blame is None or h or blame.cause[m] < 0
                 else CAUSES[blame.cause[m]])
            lines.append("| " + " | ".join([
                f"{int(f['minute'][m, h])}m{int(f['sec'][m, h]):02d}s",
                f"{f['rate_rps'][m, h]:.2f}", _fmt(f["fc_point"][m, h]),
                _fmt(f["confidence"][m, h]), f"{f['ready'][m, h]:.0f}",
                f"{f['desired'][m, h]:.0f}", f"{f['target'][m, h]:.0f}",
                " ".join(flags) or "-", c or "-"]) + " |")
    return "\n".join(lines)
