"""Decision telemetry: see what every control decision saw, attribute
every violated minute to the decision stage that caused it.

* ``trace``     — the `DecisionRecord` / `ControlTrace` schema captured
                  in-scan by ``repro.sim.cluster`` /
                  ``repro.scaling.batch`` under `telemetry=True` and
                  logged eagerly by the serving adapter (same fields, so
                  sim and engine traces diff directly).
* ``attribute`` — host-side SLO blame: walk violated minutes back
                  through the startup_sec cold-start window to the
                  responsible decision and classify the cause
                  (under-forecast / confidence-downscale /
                  cooldown-suppressed / limiter-clamped /
                  capacity-capped); blame + per-archetype tables.
* ``artifacts`` — content-addressed obs cards (trace npz + blame
                  summary + decision timeline markdown) on the
                  ``aapaset.manifest`` staged-publish scheme, rendered
                  into EXPERIMENTS.md by ``tools/render_experiments``.

Only ``trace`` loads eagerly (it is dependency-free and imported by the
sim core); ``attribute`` / ``artifacts`` resolve lazily because they
import the evals plane, which itself imports the scaling layer.
"""
from repro.obs import trace  # noqa: F401
from repro.obs.trace import (ControlTrace, DecisionRecord,  # noqa: F401
                             ExplainOut, MinuteTrace)

_LAZY = ("attribute", "artifacts")


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod = importlib.import_module(f"repro.obs.{name}")
        globals()[name] = mod
        return mod
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
