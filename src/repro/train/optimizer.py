"""AdamW in pure JAX (no optax), mixed-precision layout:

* model params stored/computed in bf16,
* f32 master weights + f32 first/second moments in the optimizer state
  (sharded identically to the params — ZeRO-style when params are
  FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    step: jax.Array
    master: Any   # f32 master weights
    m: Any        # f32 first moment
    v: Any        # f32 second moment


def init(params) -> OptState:
    return OptState(
        step=jnp.int32(0),
        master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    return cfg.lr * warm


def apply(grads, params, opt: OptState, cfg: AdamWConfig):
    """Full AdamW step. Returns (new_params (model dtype), new_opt, gnorm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt.step + 1
    lr = _schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = jax.tree.leaves(params)
    flat_ma = jax.tree.leaves(opt.master)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)

    new_p, new_ma, new_m, new_v = [], [], [], []
    for g, p, ma, m, v in zip(flat_g, flat_p, flat_ma, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m + (1 - cfg.b1) * g
        v1 = cfg.b2 * v + (1 - cfg.b2) * g * g
        ma1 = ma - lr * ((m1 / bc1) / (jnp.sqrt(v1 / bc2) + cfg.eps)
                         + cfg.weight_decay * ma)
        new_p.append(ma1.astype(p.dtype))
        new_ma.append(ma1)
        new_m.append(m1)
        new_v.append(v1)

    return (treedef.unflatten(new_p),
            OptState(step, treedef.unflatten(new_ma),
                     treedef.unflatten(new_m), treedef.unflatten(new_v)),
            gnorm)
