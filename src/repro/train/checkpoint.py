"""Fault-tolerant checkpointing (no orbax available — built from scratch).

Properties needed at 1000+ node scale (DESIGN.md §4):

* **atomic**: writes go to ``step_<N>.tmp/`` and are renamed only after
  fsync — a preemption mid-write never corrupts the latest checkpoint.
* **sharded**: each host saves only the shards it owns (here: addressable
  shards of each jax.Array); restore reassembles and reshards.
* **elastic**: ``restore(..., mesh=new_mesh)`` reshards onto a different
  mesh/topology than the one that saved — shrink/grow after node failure.
* **async**: ``AsyncCheckpointer`` snapshots to host memory synchronously
  (cheap) and writes to disk on a background thread, overlapping I/O with
  the next training steps.
* **retention**: keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str | os.PathLike, step: int, tree) -> pathlib.Path:
    """Atomic synchronous checkpoint of an arbitrary pytree of arrays."""
    root = pathlib.Path(path)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {}
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef)}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if arr.dtype == jax.numpy.bfloat16:
            arrays[f"leaf_{i}"] = arr.view(np.uint16)
            meta[f"dtype_{i}"] = "bfloat16"
        else:
            arrays[f"leaf_{i}"] = arr
            meta[f"dtype_{i}"] = str(arr.dtype)
    np.savez(tmp / "shards.npz", **arrays)
    (tmp / "meta.json").write_text(json.dumps(meta))
    with open(tmp / "meta.json", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(path: str | os.PathLike) -> int | None:
    root = pathlib.Path(path)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(path: str | os.PathLike, target_tree, *, step: int | None = None,
            mesh=None, shardings=None):
    """Restore into the structure of ``target_tree`` (a pytree of arrays or
    ShapeDtypeStructs). With ``shardings`` (pytree of NamedSharding, e.g.
    built against a *different* mesh), arrays are placed sharded —
    elastic reshard-on-restore."""
    root = pathlib.Path(path)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    z = np.load(d / "shards.npz")
    meta = json.loads((d / "meta.json").read_text())

    leaves, treedef = _flatten(target_tree)
    assert meta["n_leaves"] == len(leaves), \
        f"checkpoint has {meta['n_leaves']} leaves, target {len(leaves)}"
    out = []
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves))
    if shardings is not None:
        assert len(sh_leaves) == len(leaves)
    for i, (tgt, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = z[f"leaf_{i}"]
        if meta[f"dtype_{i}"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        assert arr.shape == tuple(tgt.shape), \
            f"leaf {i}: ckpt {arr.shape} vs target {tgt.shape}"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), step


def retain(path: str | os.PathLike, keep: int = 3) -> None:
    root = pathlib.Path(path)
    steps = sorted(int(p.name.split("_")[1]) for p in root.glob("step_*")
                   if not p.name.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(root / f"step_{s:08d}", ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, path: str | os.PathLike, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save(self.path, step, host_tree)
                retain(self.path, self.keep)
            except Exception as e:  # surfaced on next save()/wait()
                self._err = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot (blocking)
        self._q.put((step, host_tree))              # I/O overlapped

    def wait(self) -> None:
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=10)
