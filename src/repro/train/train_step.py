"""Training step: loss + grads + AdamW, with optional microbatch gradient
accumulation (lax.scan => XLA overlaps per-microbatch compute with the
FSDP all-gathers) and optional bf16 gradient compression for the
data-parallel reduction.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.train import optimizer as opt_lib


def make_train_step(cfg, opt_cfg: opt_lib.AdamWConfig = opt_lib.AdamWConfig(),
                    *, microbatches: int = 1, remat: bool = True,
                    compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch leaves have leading dim global_batch; with microbatches > 1 the
    batch splits into [microbatches, ...] and grads accumulate in a scan.
    """

    def loss(params, batch):
        return M.loss_fn(params, batch, cfg, remat=remat)

    def compute_grads(params, batch):
        if microbatches == 1:
            (l, parts), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
            return l, parts, grads

        def split(x):
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, b):
            acc, lsum = carry
            (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, b)
            if compress_grads:  # bf16 DP reduction, f32 accumulation
                g = jax.tree.map(lambda x: x.astype(jnp.bfloat16), g)
            acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (acc, lsum + l), None

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            params)
        (grads, lsum), _ = jax.lax.scan(body, (acc0, jnp.float32(0.0)), mb)
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        return lsum / microbatches, {"ce": lsum / microbatches,
                                     "aux": jnp.float32(0.0)}, grads

    def train_step(params, opt_state, batch):
        l, parts, grads = compute_grads(params, batch)
        new_params, new_opt, gnorm = opt_lib.apply(grads, params, opt_state,
                                                   opt_cfg)
        metrics = {"loss": l, "grad_norm": gnorm, **parts}
        return new_params, new_opt, metrics

    return train_step
