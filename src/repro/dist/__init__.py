# Distribution substrate: logical-axis sharding over an ambient mesh.
