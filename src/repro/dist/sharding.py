"""Logical-axis sharding: model code names axes "dp"/"mp", the mesh maps
them to physical axes.

The model and launch layers never mention physical mesh axes. They
constrain activations with logical names:

    h = shd.constrain(h, ("dp", "mp", None))

and a launcher activates a mesh once:

    rules = shd.set_mesh(make_production_mesh())

"dp" resolves to every data-parallel axis present (("pod", "data") on the
multi-pod mesh, ("data",) on a single pod), "mp" to the "model" axis. With
no active mesh every helper is a no-op / replicated, so single-device
tests and CPU smoke runs import the same model code unchanged.

Any axis that does not evenly divide a dimension is dropped from that
dimension's spec (replicated) rather than erroring — smoke configs have
tiny dims that rarely divide a production axis. Each such drop is logged
ONCE per (logical, size, dim) so a fleet run cannot silently lose its
sharding; pass ``strict=True`` to `spec` to raise instead (the fleet
evaluation plane does, via ``lane_sharding(..., strict=True)``).
"""
from __future__ import annotations

import dataclasses
import logging
import math
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_LOG = logging.getLogger(__name__)
_WARNED: set[tuple] = set()      # (logical, axis_size, dim) already logged

_DATA_AXES = ("pod", "data")   # outer-to-inner data-parallel axes
_MODEL_AXIS = "model"

Logical = Optional[str]        # "dp" | "mp" | physical axis name | None


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Resolved logical->physical axis mapping for one mesh."""

    mesh: Mesh
    dp: tuple[str, ...]        # physical data axes present in the mesh
    mp: str | None             # physical model axis, if present

    def resolve(self, logical: Logical):
        """Logical name -> PartitionSpec entry (axis name, tuple, or None)."""
        if logical is None:
            return None
        if logical == "dp":
            if not self.dp:
                return None
            return self.dp if len(self.dp) > 1 else self.dp[0]
        if logical == "mp":
            return self.mp
        return logical if logical in self.mesh.shape else None

    def axis_size(self, logical: Logical) -> int:
        if logical is None:
            return 1
        if logical == "dp":
            return math.prod(self.mesh.shape[a] for a in self.dp) \
                if self.dp else 1
        if logical == "mp":
            return self.mesh.shape[self.mp] if self.mp else 1
        return self.mesh.shape.get(logical, 1)

    def spec(self, logicals, shape, *, strict: bool = False) -> P:
        """Build a PartitionSpec, dropping axes that don't divide dims.

        A requested axis that doesn't evenly divide its dimension is
        replicated (and logged once per (logical, size, dim) triple);
        with ``strict=True`` it raises instead, so fleet-scale runs
        can't silently lose their sharding."""
        entries = []
        for i, dim in enumerate(shape):
            logical = logicals[i] if i < len(logicals) else None
            size = self.axis_size(logical)
            phys = self.resolve(logical)
            if phys is None or size <= 1:
                entries.append(None)
            elif dim % size != 0:
                if strict:
                    raise ValueError(
                        f"axis {logical!r} (size {size}) does not divide "
                        f"dim {i} of shape {tuple(shape)}; pad the dim or "
                        f"drop strict= to replicate")
                key = (logical, size, dim)
                if key not in _WARNED:
                    _WARNED.add(key)
                    _LOG.warning(
                        "sharding axis %r (size %d) does not divide dim %d"
                        " — replicating (logged once per shape)",
                        logical, size, dim)
                entries.append(None)
            else:
                entries.append(phys)
        return P(*entries)


_ACTIVE: MeshRules | None = None


def set_mesh(mesh: Mesh | None) -> MeshRules | None:
    """Activate `mesh` for all subsequent helpers; None deactivates."""
    global _ACTIVE
    if mesh is None:
        _ACTIVE = None
        return None
    names = mesh.axis_names
    _ACTIVE = MeshRules(
        mesh=mesh,
        dp=tuple(a for a in _DATA_AXES if a in names),
        mp=_MODEL_AXIS if _MODEL_AXIS in names else None)
    return _ACTIVE


def active() -> MeshRules | None:
    return _ACTIVE


def constrain(x: jax.Array, logicals) -> jax.Array:
    """with_sharding_constraint under the active mesh; identity without."""
    rules = _ACTIVE
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(logicals, x.shape)))


def lane_sharding(shape, *, w_axis: int = 1,
                  strict: bool = False) -> NamedSharding | None:
    """NamedSharding for the simulator's fused lane arrays: the workload
    axis (`w_axis`, default 1 for [P, W, ...] batches; pass 0 for a bare
    [W] / [W, M] tensor, 2 for the matrix runner's [S, Z, W, M]) shards
    over "dp", everything else replicates. Returns None with no active
    mesh so callers can skip the device_put."""
    rules = _ACTIVE
    if rules is None:
        return None
    w_axis = w_axis % max(len(shape), 1)
    logicals = tuple("dp" if i == w_axis else None
                     for i in range(len(shape)))
    return NamedSharding(rules.mesh,
                         rules.spec(logicals, shape, strict=strict))


# ------------------------------------------------------- tree shardings ----
def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _fsdp_spec(rules: MeshRules, shape) -> P:
    """ZeRO-3 style: shard the largest dp-divisible dim, replicate rest."""
    dp_size = rules.axis_size("dp")
    best = None
    if dp_size > 1 and len(shape) >= 1:
        divisible = [i for i, d in enumerate(shape)
                     if d % dp_size == 0 and d >= dp_size]
        if divisible:
            best = max(divisible, key=lambda i: shape[i])
    entries = [rules.resolve("dp") if i == best else None
               for i in range(len(shape))]
    return P(*entries)


def param_shardings(tree: Any):
    """NamedSharding pytree for params (or same-structured trees like the
    optimizer's master/m/v). Expert weights shard E over "mp" and D over
    "dp" (matching the shard_map EP path in repro.models.moe); everything
    else is FSDP-sharded over "dp". Scalars and vectors replicate."""
    rules = _ACTIVE
    if rules is None:
        raise RuntimeError("param_shardings requires set_mesh(...) first")

    def one(path, leaf):
        name = _path_name(path)
        shape = leaf.shape
        if len(shape) <= 1:
            return NamedSharding(rules.mesh, P())
        if name.endswith(("w_gate", "w_up")) and len(shape) == 3:
            return NamedSharding(rules.mesh,
                                 rules.spec(("mp", "dp", None), shape))
        if name.endswith("w_down") and len(shape) == 3:
            return NamedSharding(rules.mesh,
                                 rules.spec(("mp", None, "dp"), shape))
        if name.endswith("router"):
            return NamedSharding(rules.mesh, P())
        return NamedSharding(rules.mesh, _fsdp_spec(rules, shape))

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_shardings(tree: Any):
    """Shard the leading (batch) dim of every leaf over "dp"."""
    rules = _ACTIVE
    if rules is None:
        raise RuntimeError("batch_shardings requires set_mesh(...) first")

    def one(leaf):
        return NamedSharding(rules.mesh,
                             rules.spec(("dp",), leaf.shape))

    return jax.tree.map(one, tree)


def cache_shardings(cache: Any, cfg):
    """Decode-cache shardings: batch dim over "dp" (axis 1 for the
    lax.scan-stacked per-layer subtrees, axis 0 for the unstacked leading
    dense layers)."""
    rules = _ACTIVE
    if rules is None:
        raise RuntimeError("cache_shardings requires set_mesh(...) first")

    def one(path, leaf):
        name = _path_name(path)
        batch_axis = 0 if name.startswith("dense_layers") else 1
        if len(leaf.shape) <= batch_axis:
            return NamedSharding(rules.mesh, P())
        logicals = [None] * len(leaf.shape)
        logicals[batch_axis] = "dp"
        return NamedSharding(rules.mesh,
                             rules.spec(tuple(logicals), leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache)
