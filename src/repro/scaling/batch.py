"""Batched multi-policy simulation: policies x workloads in ONE compile.

``make_simulator`` (one policy, vmapped workloads) compiles one scan per
policy — benchmarks that sweep policies pay the XLA compile N times and
dispatch N times. This module folds the policy axis into the same
compiled call:

* `make_batch_simulator(controllers, cfg)` — arbitrary (heterogeneous)
  controllers. ONE control-period-blocked scan advances all P x W plant
  lanes as fused [P, W] vectors, and at each block head every controller
  runs its `decide` exactly once on its own [W] row of the lanes: one
  compile, one dispatch, exactly P (not P^2) decide evaluations per
  control step, with the plant dynamics amortized across the whole
  P x W batch. This replaced a design that carried every controller's
  state in every lane and selected by index — O(P^2) duplicated
  `decide` FLOPs per control step (benchmarks/bench_sim.py keeps that
  shape as its measured baseline). Lane (p, w) reproduces
  `simulate(rates[w], controllers[p])` (pinned to tolerance by
  tests/test_scaling.py — compiled embeddings differ, so last-ulp
  equality is not guaranteed, see tests/test_sim_blocked.py).

  The W axis is the fleet axis: every lane field keeps W as its second
  dimension and is constrained over the ``repro.dist.sharding`` "dp"
  axis each minute, so activating a mesh (`shd.set_mesh`) shards the
  whole episode scan across devices with no code change — each device
  advances its W-shard of every policy's lanes and only the episode-end
  reductions communicate. With no active mesh the constraints are
  no-ops. `w_chunk=` additionally scans over W-chunks of the workload
  axis inside one dispatch so the live plant state is [P, w_chunk]
  regardless of W (the fleet-scale front door over this is
  ``repro.evals.fleet``).

* `make_grid_simulator(name, grid, cfg)` — same-structured controllers
  (one registry family, hyperparameters declared `stackable`). The
  hyperparameters are stacked into arrays and the *factory itself* is
  traced with per-lane scalars, so the policy axis is a true vmap with
  no per-slot duplication at all. This is the cheap path for
  hyperparameter sweeps (target CPU, panic thresholds, guardrail
  fractions...).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.scaling import registry
from repro.scaling.api import (Controller, LimiterState, Obs,
                               apply_decision)
from repro.sim.cluster import (MinuteOut, SimConfig, advance_plant,
                               simulate, _acc_fold, _acc_init,
                               _apply_scaling, _flow_tick, _pop_pipeline,
                               initial_state)


class BatchState(NamedTuple):
    """Plant state for P x W fused lanes plus the per-controller control
    states (leaves lead with [W]). W is the fleet/sharding axis: every
    lane field keeps it second so `constrain_lanes` can pin it to the
    "dp" mesh axis."""
    ready: jax.Array         # [P, W]
    pipeline: jax.Array      # [P, W, startup_sec]
    pipe_sum: jax.Array      # [P, W]
    queue: jax.Array         # [P, W]
    wait_sum: jax.Array      # [P, W]
    util_ema: jax.Array      # [P, W]
    cooldown: jax.Array      # [P, W]
    last_dir: jax.Array      # [P, W]
    rate_history: jax.Array  # [W, history_len] (shared across policies)
    ctrl: tuple              # per-controller state pytrees, leaves [W, ...]


def batch_initial_state(ctrls, W: int, cfg: SimConfig) -> BatchState:
    P = len(ctrls)
    st = initial_state(ctrls[0], cfg)

    def rep(x):
        return jnp.broadcast_to(x, (P, W) + jnp.shape(x))

    return BatchState(
        ready=rep(st.ready), pipeline=rep(st.pipeline),
        pipe_sum=rep(st.pipe_sum), queue=rep(st.queue),
        wait_sum=rep(st.wait_sum), util_ema=rep(st.util_ema),
        cooldown=jnp.zeros((P, W), jnp.float32),
        last_dir=jnp.zeros((P, W), jnp.float32),
        rate_history=jnp.zeros((W, cfg.history_len), jnp.float32),
        ctrl=tuple(jax.vmap(lambda _, c=c: c.init())(jnp.arange(W))
                   for c in ctrls))


def constrain_lanes(state: BatchState) -> BatchState:
    """Constrain every lane field's workload axis over the "dp" mesh
    axis (no-op without an active mesh): [P, W, ...] fields shard dim 1,
    rate_history and the per-controller [W, ...] states shard dim 0."""
    lanes = {f: shd.constrain(getattr(state, f), (None, "dp"))
             for f in ("ready", "pipeline", "pipe_sum", "queue",
                       "wait_sum", "util_ema", "cooldown", "last_dir")}
    return state._replace(
        rate_history=shd.constrain(state.rate_history, ("dp",)),
        ctrl=jax.tree.map(lambda x: shd.constrain(x, ("dp",)), state.ctrl),
        **lanes)


def _batch_ctrl_tick(cfg, ctrls, state: BatchState, acc, arr_w,
                     minute_idx):
    """Block-head tick for all lanes: fused plant flow on [P, W], then
    each controller's decide vmapped over ITS [W] row (P decide
    subgraphs total), then the shared scaling semantics back on [P, W].
    The plant pieces are cluster.py's own shape-agnostic helpers, so the
    batched and single-lane dynamics cannot drift apart."""
    ready, pipeline, pipe_sum = _pop_pipeline(
        state.ready, state.pipeline, state.pipe_sum)

    arr_pw = jnp.broadcast_to(arr_w, ready.shape)
    (queue, wait_sum, util_ema, served, violated, cold, resp,
     util) = _flow_tick(cfg, ready, state.queue, state.wait_sum,
                        state.util_ema, arr_pw)

    W = arr_w.shape[0]
    total = ready + pipe_sum
    new_ctrl, desired, cool_req = [], [], []
    for p, c in enumerate(ctrls):
        obs = Obs(ready_total=total[p], ready=ready[p],
                  util_ema=util_ema[p], queue=queue[p], rate_rps=arr_w,
                  rate_history=state.rate_history, minute_idx=minute_idx)
        cs, des, coo = jax.vmap(
            c.decide, in_axes=(0, Obs(0, 0, 0, 0, 0, 0, None)))(
                state.ctrl[p], obs)
        new_ctrl.append(cs)
        desired.append(jnp.asarray(des, jnp.float32))
        cool_req.append(jnp.broadcast_to(
            jnp.asarray(coo, jnp.float32), (W,)))
    desired = jnp.clip(jnp.stack(desired), 0.0, cfg.max_replicas)
    cool_req = jnp.stack(cool_req)

    lim, act = apply_decision(
        LimiterState(cooldown=state.cooldown, last_dir=state.last_dir),
        total, desired, cool_req, jnp.bool_(True), dt=1.0)
    ready, pipeline, pipe_sum = _apply_scaling(ready, pipeline, pipe_sum,
                                               act)

    state = BatchState(ready=ready, pipeline=pipeline, pipe_sum=pipe_sum,
                       queue=queue, wait_sum=wait_sum, util_ema=util_ema,
                       cooldown=lim.cooldown, last_dir=lim.last_dir,
                       rate_history=state.rate_history,
                       ctrl=tuple(new_ctrl))
    acc = _acc_fold(acc, (served, violated, cold, ready + pipe_sum, resp,
                          util, act.scale_up.astype(jnp.float32),
                          act.scale_down.astype(jnp.float32),
                          act.oscillation, ready))
    return state, acc


def _batch_plant_block(cfg, state: BatchState, acc, arr_pw, n_ticks: int):
    """`n_ticks` decision-free ticks for all [P, W] lanes — exactly
    cluster.advance_plant on the batched fields."""
    (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
     cool), acc = advance_plant(
        cfg, state.ready, state.pipeline, state.pipe_sum, state.queue,
        state.wait_sum, state.util_ema, state.cooldown, acc, arr_pw,
        n_ticks)
    state = state._replace(
        ready=ready, pipeline=pipeline, pipe_sum=pipe_sum, queue=queue,
        wait_sum=wait_sum, util_ema=util_ema, cooldown=cool)
    return state, acc


def make_batch_minute_step(controllers: Sequence[Controller],
                           cfg: SimConfig = SimConfig(), *,
                           shard: bool = True):
    """(BatchState carry, minute_idx, rate_w [W]) stepping function for
    the fused P x W batch: returns per-minute MinuteOut of [P, W]
    arrays. `repro.evals.matrix` scans this directly with its metric
    accumulator in the carry; `make_batch_simulator` wraps it for
    materialized [P, W, M] outputs. `decide` runs exactly once per
    controller per control step (O(P), not O(P^2)). With `shard` (the
    default) every carry field is constrained over the "dp" mesh axis
    once per minute — a no-op without an active mesh."""
    ctrls = list(controllers)
    P = len(ctrls)
    ci = max(min(int(cfg.control_interval_sec), 60), 1)
    n_full = 60 // ci
    tail = 60 - n_full * ci

    def step(state: BatchState, minute_idx, rate_w):
        if shard:
            state = constrain_lanes(state)
            rate_w = shd.constrain(rate_w, ("dp",))
        W = rate_w.shape[0]
        arr_w = rate_w / 60.0
        arr_pw = jnp.broadcast_to(arr_w, (P, W))
        acc = tuple(jnp.zeros((P, W), jnp.float32) for _ in _acc_init())

        def block(st, a, n_ticks):
            st, a = _batch_ctrl_tick(cfg, ctrls, st, a, arr_w, minute_idx)
            if n_ticks > 1:
                st, a = _batch_plant_block(cfg, st, a, arr_pw, n_ticks - 1)
            return st, a

        if n_full == 1:
            state, acc = block(state, acc, ci)
        elif n_full:
            def body(carry, _):
                return block(*carry, ci), None
            (state, acc), _ = jax.lax.scan(body, (state, acc), None,
                                           length=n_full)
        if tail:
            state, acc = block(state, acc, tail)

        m = MinuteOut(
            served=acc[0], violated=acc[1], cold_starts=acc[2],
            replica_seconds=acc[3], queue_end=state.queue, resp_sum=acc[4],
            resp_max=acc[5], ups=acc[6], downs=acc[7], oscillations=acc[8],
            util_mean=acc[9] / 60.0, ready_mean=acc[10] / 60.0)

        hist = jnp.concatenate(
            [state.rate_history[:, 1:], rate_w[:, None]], axis=1)
        ctrl = tuple(
            jax.vmap(c.on_minute, in_axes=(0, 0, None))(s, hist,
                                                        minute_idx + 1)
            for c, s in zip(ctrls, state.ctrl))
        state = state._replace(rate_history=hist, ctrl=ctrl)
        return state, m

    return step


def make_batch_simulator(controllers: Sequence[Controller],
                         cfg: SimConfig = SimConfig(), *,
                         plant_kernel: bool | None = None,
                         shard: bool = True, w_chunk: int | None = None,
                         donate: bool = False):
    """jit: rates [W, M] -> MinuteOut [P, W, M]. One compile, one
    dispatch: a single blocked scan over fused P x W plant lanes with
    exactly P (not P^2) decide evaluations per control step.
    (`plant_kernel` is accepted for signature parity with
    `make_simulator`; the fused-lane batch always uses the vector plant
    path, which IS the kernel's oracle.)

    `w_chunk` scans over chunks of the workload axis inside the same
    dispatch, so the live plant state is [P, w_chunk] however large W
    grows (the chunks are independent episodes; requires
    W % w_chunk == 0). `donate` donates the rates buffer to the call.
    """
    del plant_kernel
    ctrls = list(controllers)
    step = make_batch_minute_step(ctrls, cfg, shard=shard)

    def episode(rates):                       # [Wc, M] -> [P, Wc, M]
        W, M = rates.shape

        def minute(carry, rate_w):
            state, idx = carry
            state, m = step(state, idx, rate_w)
            return (state, idx + 1), m

        (_, _), out = jax.lax.scan(
            minute, (batch_initial_state(ctrls, W, cfg), jnp.int32(0)),
            rates.T)
        return jax.tree.map(lambda a: jnp.moveaxis(a, 0, -1), out)

    def run(rates):
        rates = rates.astype(jnp.float32)
        W, M = rates.shape
        if w_chunk is None or w_chunk >= W:
            return episode(rates)
        if W % w_chunk:
            raise ValueError(f"w_chunk {w_chunk} must divide W {W}")
        chunked = rates.reshape(W // w_chunk, w_chunk, M)
        _, out = jax.lax.scan(lambda c, r: (c, episode(r)), 0, chunked)
        # [C, P, Wc, M] -> [P, W, M]
        return jax.tree.map(
            lambda a: jnp.moveaxis(a, 0, 1).reshape(
                a.shape[1], W, a.shape[3]), out)

    return jax.jit(run, donate_argnums=(0,) if donate else ())


def batch_simulate(controllers: Sequence[Controller], rates,
                   cfg: SimConfig = SimConfig()) -> MinuteOut:
    """Convenience wrapper: rates [W, M] -> MinuteOut of [P, W, M]."""
    return make_batch_simulator(controllers, cfg)(jnp.asarray(rates))


def make_forecast_batch_simulator(policies: Sequence[str],
                                  forecasters: Sequence,
                                  cfg: SimConfig = SimConfig(), *,
                                  classify=None, **overrides):
    """Forecasters x policies x workloads in ONE compiled call.

    Every policy must be forecaster-aware (`takes_forecaster` in its
    registry spec: `predictive`, `aapa`, `hybrid`); `forecasters` are
    ``repro.forecast.registry`` names or Forecaster instances. Returns a
    fn rates [W, M] -> MinuteOut [F, P, W, M]; lane (f, p) is bit-for-bit
    the standalone simulation of policy p using forecaster f (pinned by
    tests/test_forecast.py)."""
    aware = [n for n in registry.available()
             if registry.spec(n).takes_forecaster]
    for p in policies:
        if not registry.spec(p).takes_forecaster:
            raise TypeError(f"policy {p!r} takes no forecaster; "
                            f"forecaster-aware policies: {aware}")
    ctrls = [registry.get_controller(p, cfg, classify=classify,
                                     forecaster=f, **overrides)
             for f in forecasters for p in policies]
    sim = make_batch_simulator(ctrls, cfg)
    shape = (len(forecasters), len(policies))

    def run(rates):
        out = sim(jnp.asarray(rates))                 # [F*P, W, M]
        return jax.tree.map(
            lambda a: a.reshape(shape + a.shape[1:]), out)

    return run


def make_grid_simulator(name: str, grid: Sequence[dict],
                        cfg: SimConfig = SimConfig(), *,
                        classify=None, **fixed):
    """One policy family, a grid of hyperparameter points, one compile.

    `grid` is a list of dicts over the family's `stackable` keys; every
    point must set the same keys. Returns a jitted fn
    rates [W, M] -> MinuteOut [len(grid), W, M].
    """
    sp = registry.spec(name)
    if not grid:
        raise ValueError("empty hyperparameter grid")
    keys = sorted(grid[0])
    bad = set(keys) - set(sp.stackable)
    if bad:
        raise TypeError(f"policy {name!r} cannot stack {sorted(bad)}; "
                        f"stackable: {sorted(sp.stackable)}")
    for g in grid:
        if sorted(g) != keys:
            raise ValueError("every grid point must set the same keys")
    stacked = {k: jnp.asarray([float(g[k]) for g in grid], jnp.float32)
               for k in keys}

    def sim_one(hyper, rates):
        kw = dict(sp.defaults)
        kw.update(fixed)
        kw.update(hyper)       # traced per-lane scalars
        if sp.needs_classifier:
            ctrl = sp.factory(cfg, classify or registry.default_classify,
                              **kw)
        else:
            ctrl = sp.factory(cfg, **kw)
        return simulate(rates, ctrl, cfg)

    over_workloads = jax.vmap(sim_one, in_axes=(None, 0))
    over_grid = jax.vmap(over_workloads, in_axes=(0, None))
    return jax.jit(lambda rates: over_grid(
        stacked, jnp.asarray(rates, jnp.float32)))
