"""Batched multi-policy simulation: policies x workloads in ONE compile.

``make_simulator`` (one policy, vmapped workloads) compiles one scan per
policy — benchmarks that sweep policies pay the XLA compile N times and
dispatch N times. This module folds the policy axis into the same
compiled call:

* `make_batch_simulator(controllers, cfg)` — arbitrary (heterogeneous)
  controllers. ONE control-period-blocked scan advances all P x W plant
  lanes as fused [P, W] vectors, and at each block head every controller
  runs its `decide` exactly once on its own [W] row of the lanes: one
  compile, one dispatch, exactly P (not P^2) decide evaluations per
  control step, with the plant dynamics amortized across the whole
  P x W batch. This replaced a design that carried every controller's
  state in every lane and selected by index — O(P^2) duplicated
  `decide` FLOPs per control step (benchmarks/bench_sim.py keeps that
  shape as its measured baseline). Lane (p, w) reproduces
  `simulate(rates[w], controllers[p])` (pinned to tolerance by
  tests/test_scaling.py — compiled embeddings differ, so last-ulp
  equality is not guaranteed, see tests/test_sim_blocked.py).

  The W axis is the fleet axis: every lane field keeps W as its second
  dimension and is constrained over the ``repro.dist.sharding`` "dp"
  axis each minute, so activating a mesh (`shd.set_mesh`) shards the
  whole episode scan across devices with no code change — each device
  advances its W-shard of every policy's lanes and only the episode-end
  reductions communicate. With no active mesh the constraints are
  no-ops. `w_chunk=` additionally scans over W-chunks of the workload
  axis inside one dispatch so the live plant state is [P, w_chunk]
  regardless of W (the fleet-scale front door over this is
  ``repro.evals.fleet``).

* `make_grid_simulator(name, grid, cfg)` — same-structured controllers
  (one registry family). Hyperparameters split two ways: `stackable`
  keys are stacked into arrays and the *factory itself* is traced with
  per-lane scalars (the policy axis is a true vmap with no per-slot
  duplication); the remaining *static* keys (`horizon_min`,
  `stride_min`, `stabilization_min`, ...) change compiled structure, so
  the grid groups by static values and compiles once per group. This is
  the cheap path for hyperparameter sweeps (target CPU, panic
  thresholds, guardrail fractions...).

* `make_grid_evaluator(name, cfg)` — the same fused grid lanes with
  `repro.evals.metrics` accumulators carried inside the scan: candidates
  come back as pooled EpisodeMetrics + REI without ever materializing a
  [G, W, M] MinuteOut tensor. ``repro.tuning`` drives its searches
  through this.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.obs import trace as obs_trace
from repro.scaling import registry
from repro.scaling.api import (Controller, LimiterState, Obs,
                               apply_decision)
from repro.sim.cluster import (MinuteOut, SimConfig, advance_plant,
                               minute_step, simulate, _acc_fold,
                               _acc_init, _apply_scaling, _flow_tick,
                               _pop_pipeline, initial_state)


class BatchState(NamedTuple):
    """Plant state for P x W fused lanes plus the per-controller control
    states (leaves lead with [W]). W is the fleet/sharding axis: every
    lane field keeps it second so `constrain_lanes` can pin it to the
    "dp" mesh axis."""
    ready: jax.Array         # [P, W]
    pipeline: jax.Array      # [P, W, startup_sec]
    pipe_sum: jax.Array      # [P, W]
    queue: jax.Array         # [P, W]
    wait_sum: jax.Array      # [P, W]
    util_ema: jax.Array      # [P, W]
    cooldown: jax.Array      # [P, W]
    last_dir: jax.Array      # [P, W]
    rate_history: jax.Array  # [W, history_len] (shared across policies)
    ctrl: tuple              # per-controller state pytrees, leaves [W, ...]


def batch_initial_state(ctrls, W: int, cfg: SimConfig) -> BatchState:
    P = len(ctrls)
    st = initial_state(ctrls[0], cfg)

    def rep(x):
        return jnp.broadcast_to(x, (P, W) + jnp.shape(x))

    return BatchState(
        ready=rep(st.ready), pipeline=rep(st.pipeline),
        pipe_sum=rep(st.pipe_sum), queue=rep(st.queue),
        wait_sum=rep(st.wait_sum), util_ema=rep(st.util_ema),
        cooldown=jnp.zeros((P, W), jnp.float32),
        last_dir=jnp.zeros((P, W), jnp.float32),
        rate_history=jnp.zeros((W, cfg.history_len), jnp.float32),
        ctrl=tuple(jax.vmap(lambda _, c=c: c.init())(jnp.arange(W))
                   for c in ctrls))


def constrain_lanes(state: BatchState) -> BatchState:
    """Constrain every lane field's workload axis over the "dp" mesh
    axis (no-op without an active mesh): [P, W, ...] fields shard dim 1,
    rate_history and the per-controller [W, ...] states shard dim 0."""
    lanes = {f: shd.constrain(getattr(state, f), (None, "dp"))
             for f in ("ready", "pipeline", "pipe_sum", "queue",
                       "wait_sum", "util_ema", "cooldown", "last_dir")}
    return state._replace(
        rate_history=shd.constrain(state.rate_history, ("dp",)),
        ctrl=jax.tree.map(lambda x: shd.constrain(x, ("dp",)), state.ctrl),
        **lanes)


def _batch_ctrl_tick(cfg, ctrls, state: BatchState, acc, arr_w,
                     minute_idx, telemetry: bool = False, head_sec=0.0):
    """Block-head tick for all lanes: fused plant flow on [P, W], then
    each controller's decide vmapped over ITS [W] row (P decide
    subgraphs total), then the shared scaling semantics back on [P, W].
    The plant pieces are cluster.py's own shape-agnostic helpers, so the
    batched and single-lane dynamics cannot drift apart. `telemetry`
    (static) additionally returns a [P, W] DecisionRecord; the False
    path is op-for-op the pre-telemetry program."""
    ready, pipeline, pipe_sum = _pop_pipeline(
        state.ready, state.pipeline, state.pipe_sum)

    arr_pw = jnp.broadcast_to(arr_w, ready.shape)
    (queue, wait_sum, util_ema, served, violated, cold, resp,
     util) = _flow_tick(cfg, ready, state.queue, state.wait_sum,
                        state.util_ema, arr_pw)

    W = arr_w.shape[0]
    total = ready + pipe_sum
    new_ctrl, desired, cool_req, exps = [], [], [], []
    for p, c in enumerate(ctrls):
        obs = Obs(ready_total=total[p], ready=ready[p],
                  util_ema=util_ema[p], queue=queue[p], rate_rps=arr_w,
                  rate_history=state.rate_history, minute_idx=minute_idx)
        cs, des, coo = jax.vmap(
            c.decide, in_axes=(0, Obs(0, 0, 0, 0, 0, 0, None)))(
                state.ctrl[p], obs)
        new_ctrl.append(cs)
        desired.append(jnp.asarray(des, jnp.float32))
        cool_req.append(jnp.broadcast_to(
            jnp.asarray(coo, jnp.float32), (W,)))
        if telemetry:
            exps.append(jax.vmap(
                c.explain, in_axes=(0, Obs(0, 0, 0, 0, 0, 0, None)))(
                    state.ctrl[p], obs)
                if getattr(c, "explain", None) is not None
                else obs_trace.explain_nan((W,)))
    desired_raw = jnp.stack(desired)
    desired = jnp.clip(desired_raw, 0.0, cfg.max_replicas)
    cool_req = jnp.stack(cool_req)

    cooldown_before = state.cooldown
    lim, act = apply_decision(
        LimiterState(cooldown=state.cooldown, last_dir=state.last_dir),
        total, desired, cool_req, jnp.bool_(True), dt=1.0)
    ready_at_decision = ready
    ready, pipeline, pipe_sum = _apply_scaling(ready, pipeline, pipe_sum,
                                               act)

    state = BatchState(ready=ready, pipeline=pipeline, pipe_sum=pipe_sum,
                       queue=queue, wait_sum=wait_sum, util_ema=util_ema,
                       cooldown=lim.cooldown, last_dir=lim.last_dir,
                       rate_history=state.rate_history,
                       ctrl=tuple(new_ctrl))
    acc = _acc_fold(acc, (served, violated, cold, ready + pipe_sum, resp,
                          util, act.scale_up.astype(jnp.float32),
                          act.scale_down.astype(jnp.float32),
                          act.oscillation, ready))
    if not telemetry:
        return state, acc
    exp = jax.tree.map(lambda *xs: jnp.stack(xs), *exps)      # [P, W]
    rec = obs_trace.record(
        cfg, minute_idx=minute_idx, sec=head_sec,
        ready=ready_at_decision, total=total, queue=queue,
        util_ema=util_ema, rate_rps=arr_pw, exp=exp,
        desired_raw=desired_raw, desired=desired, cooldown_req=cool_req,
        cooldown_before=cooldown_before, act=act)
    return state, acc, rec


def _batch_plant_block(cfg, state: BatchState, acc, arr_pw, n_ticks: int):
    """`n_ticks` decision-free ticks for all [P, W] lanes — exactly
    cluster.advance_plant on the batched fields."""
    (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
     cool), acc = advance_plant(
        cfg, state.ready, state.pipeline, state.pipe_sum, state.queue,
        state.wait_sum, state.util_ema, state.cooldown, acc, arr_pw,
        n_ticks)
    state = state._replace(
        ready=ready, pipeline=pipeline, pipe_sum=pipe_sum, queue=queue,
        wait_sum=wait_sum, util_ema=util_ema, cooldown=cool)
    return state, acc


def make_batch_minute_step(controllers: Sequence[Controller],
                           cfg: SimConfig = SimConfig(), *,
                           shard: bool = True, telemetry: bool = False,
                           trace_lanes: int | None = None):
    """(BatchState carry, minute_idx, rate_w [W]) stepping function for
    the fused P x W batch: returns per-minute MinuteOut of [P, W]
    arrays. `repro.evals.matrix` scans this directly with its metric
    accumulator in the carry; `make_batch_simulator` wraps it for
    materialized [P, W, M] outputs. `decide` runs exactly once per
    controller per control step (O(P), not O(P^2)). With `shard` (the
    default) every carry field is constrained over the "dp" mesh axis
    once per minute — a no-op without an active mesh.

    With `telemetry` (static) each step returns ``(state, (MinuteOut
    [P, W], ControlTrace))`` — decisions leaves [H, P, K], minutes
    leaves [P, K], where H is the block-head count and K the traced
    lane count: `trace_lanes` bounds capture to K deterministically
    sampled lanes (``repro.obs.trace.sample_lanes``) so fleet-scale
    scans stay O(P * bins) in the carry and O(K) in the trace ys. The
    default path is untouched."""
    ctrls = list(controllers)
    P = len(ctrls)
    ci = max(min(int(cfg.control_interval_sec), 60), 1)
    n_full = 60 // ci
    tail = 60 - n_full * ci

    def step(state: BatchState, minute_idx, rate_w):
        if shard:
            state = constrain_lanes(state)
            rate_w = shd.constrain(rate_w, ("dp",))
        W = rate_w.shape[0]
        arr_w = rate_w / 60.0
        arr_pw = jnp.broadcast_to(arr_w, (P, W))
        acc = tuple(jnp.zeros((P, W), jnp.float32) for _ in _acc_init())

        if telemetry:
            return _step_telemetry(state, minute_idx, rate_w, arr_w,
                                   arr_pw, acc, W)

        def block(st, a, n_ticks):
            st, a = _batch_ctrl_tick(cfg, ctrls, st, a, arr_w, minute_idx)
            if n_ticks > 1:
                st, a = _batch_plant_block(cfg, st, a, arr_pw, n_ticks - 1)
            return st, a

        if n_full == 1:
            state, acc = block(state, acc, ci)
        elif n_full:
            def body(carry, _):
                return block(*carry, ci), None
            (state, acc), _ = jax.lax.scan(body, (state, acc), None,
                                           length=n_full)
        if tail:
            state, acc = block(state, acc, tail)

        return _finish(state, minute_idx, rate_w, acc)

    def _step_telemetry(state, minute_idx, rate_w, arr_w, arr_pw, acc, W):
        idx = obs_trace.sample_lanes(W, trace_lanes)   # None keeps all

        def block(st, a, n_ticks, head_sec):
            st, a, rec = _batch_ctrl_tick(cfg, ctrls, st, a, arr_w,
                                          minute_idx, telemetry=True,
                                          head_sec=head_sec)
            if n_ticks > 1:
                st, a = _batch_plant_block(cfg, st, a, arr_pw, n_ticks - 1)
            if idx is not None:
                rec = jax.tree.map(lambda x: x[..., idx], rec)
            return st, a, rec

        recs = []
        if n_full == 1:
            state, acc, rec = block(state, acc, ci, jnp.float32(0.0))
            recs.append(jax.tree.map(lambda x: x[None], rec))
        elif n_full:
            def body(carry, head_sec):
                st, a, rec = block(*carry, ci, head_sec)
                return (st, a), rec
            (state, acc), rec = jax.lax.scan(
                body, (state, acc),
                jnp.arange(n_full, dtype=jnp.float32) * ci)
            recs.append(rec)
        if tail:
            state, acc, rec = block(state, acc, tail,
                                    jnp.float32(n_full * ci))
            recs.append(jax.tree.map(lambda x: x[None], rec))
        decisions = (recs[0] if len(recs) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *recs))  # [H, P, K]

        state, m = _finish(state, minute_idx, rate_w, acc)
        sel = (lambda a: a) if idx is None else (lambda a: a[..., idx])
        mt = obs_trace.MinuteTrace(
            rate=jnp.broadcast_to(sel(rate_w), sel(m.served).shape),
            served=sel(m.served), violated=sel(m.violated),
            queue_end=sel(m.queue_end), ready_mean=sel(m.ready_mean))
        return state, (m, obs_trace.ControlTrace(decisions=decisions,
                                                 minutes=mt))

    def _finish(state, minute_idx, rate_w, acc):
        m = MinuteOut(
            served=acc[0], violated=acc[1], cold_starts=acc[2],
            replica_seconds=acc[3], queue_end=state.queue, resp_sum=acc[4],
            resp_max=acc[5], ups=acc[6], downs=acc[7], oscillations=acc[8],
            util_mean=acc[9] / 60.0, ready_mean=acc[10] / 60.0)

        hist = jnp.concatenate(
            [state.rate_history[:, 1:], rate_w[:, None]], axis=1)
        ctrl = tuple(
            jax.vmap(c.on_minute, in_axes=(0, 0, None))(s, hist,
                                                        minute_idx + 1)
            for c, s in zip(ctrls, state.ctrl))
        state = state._replace(rate_history=hist, ctrl=ctrl)
        return state, m

    return step


def make_batch_simulator(controllers: Sequence[Controller],
                         cfg: SimConfig = SimConfig(), *,
                         plant_kernel: bool | None = None,
                         decide_kernel: bool | None = None,
                         shard: bool = True, w_chunk: int | None = None,
                         donate: bool = False, telemetry: bool = False,
                         trace_lanes: int | None = None):
    """jit: rates [W, M] -> MinuteOut [P, W, M]. One compile, one
    dispatch: a single blocked scan over fused P x W plant lanes with
    exactly P (not P^2) decide evaluations per control step.
    (`plant_kernel` is accepted for signature parity with
    `make_simulator`; the fused-lane batch always uses the vector plant
    path, which IS the kernel's oracle.)

    `decide_kernel` (auto on TPU, same dispatch as
    ``cluster.make_simulator``) instead runs one fused-decide episode
    kernel per controller over the W lanes — every policy's whole
    episode on-chip (``repro.kernels.episode_block``), stacked back to
    [P, W, M], still one compile. The off path is the unchanged fused
    P x W scan. Incompatible with `telemetry` (decisions stay on-chip).

    `w_chunk` scans over chunks of the workload axis inside the same
    dispatch, so the live plant state is [P, w_chunk] however large W
    grows (the chunks are independent episodes; requires
    W % w_chunk == 0). `donate` donates the rates buffer to the call.

    `telemetry` returns ``(MinuteOut [P, W, M], ControlTrace)`` with the
    trace time-major: decisions leaves [M, H, P, K], minutes leaves
    [M, P, K] (K = `trace_lanes` sampled lanes, all W when None);
    incompatible with `w_chunk` — chunked capture is what
    ``repro.evals.fleet`` is for: pass `trace_lanes` on its `FleetSpec`
    to stream sampled-lane traces per chunk.
    """
    del plant_kernel
    if telemetry and w_chunk is not None:
        raise ValueError(
            "telemetry does not compose with w_chunk here; for chunked "
            "capture use repro.evals.fleet with trace_lanes "
            "(FleetSpec(..., trace_lanes=K) samples K lanes per chunk)")
    from repro.sim.cluster import (_reject_decide_kernel_telemetry,
                                   _use_decide_kernel)
    use_dk = _use_decide_kernel(decide_kernel)
    if use_dk and telemetry:
        _reject_decide_kernel_telemetry()
    ctrls = list(controllers)
    step = make_batch_minute_step(ctrls, cfg, shard=shard,
                                  telemetry=telemetry,
                                  trace_lanes=trace_lanes)

    def episode(rates):                       # [Wc, M] -> [P, Wc, M]
        W, M = rates.shape
        if use_dk:
            from repro.kernels import ops
            outs = [ops.episode_block(rates, c, cfg) for c in ctrls]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)

        def minute(carry, rate_w):
            state, idx = carry
            state, m = step(state, idx, rate_w)
            return (state, idx + 1), m

        (_, _), out = jax.lax.scan(
            minute, (batch_initial_state(ctrls, W, cfg), jnp.int32(0)),
            rates.T)
        if telemetry:
            m, ct = out       # the trace stays time-major ([M, ...])
            return jax.tree.map(lambda a: jnp.moveaxis(a, 0, -1), m), ct
        return jax.tree.map(lambda a: jnp.moveaxis(a, 0, -1), out)

    def run(rates):
        rates = rates.astype(jnp.float32)
        W, M = rates.shape
        if w_chunk is None or w_chunk >= W:
            return episode(rates)
        if W % w_chunk:
            raise ValueError(f"w_chunk {w_chunk} must divide W {W}")
        chunked = rates.reshape(W // w_chunk, w_chunk, M)
        _, out = jax.lax.scan(lambda c, r: (c, episode(r)), 0, chunked)
        # [C, P, Wc, M] -> [P, W, M]
        return jax.tree.map(
            lambda a: jnp.moveaxis(a, 0, 1).reshape(
                a.shape[1], W, a.shape[3]), out)

    return jax.jit(run, donate_argnums=(0,) if donate else ())


def batch_simulate(controllers: Sequence[Controller], rates,
                   cfg: SimConfig = SimConfig()) -> MinuteOut:
    """Convenience wrapper: rates [W, M] -> MinuteOut of [P, W, M]."""
    return make_batch_simulator(controllers, cfg)(jnp.asarray(rates))


def make_forecast_batch_simulator(policies: Sequence[str],
                                  forecasters: Sequence,
                                  cfg: SimConfig = SimConfig(), *,
                                  classify=None, **overrides):
    """Forecasters x policies x workloads in ONE compiled call.

    Every policy must be forecaster-aware (`takes_forecaster` in its
    registry spec: `predictive`, `aapa`, `hybrid`); `forecasters` are
    ``repro.forecast.registry`` names or Forecaster instances. Returns a
    fn rates [W, M] -> MinuteOut [F, P, W, M]; lane (f, p) is bit-for-bit
    the standalone simulation of policy p using forecaster f (pinned by
    tests/test_forecast.py)."""
    aware = [n for n in registry.available()
             if registry.spec(n).takes_forecaster]
    for p in policies:
        if not registry.spec(p).takes_forecaster:
            raise TypeError(f"policy {p!r} takes no forecaster; "
                            f"forecaster-aware policies: {aware}")
    ctrls = [registry.get_controller(p, cfg, classify=classify,
                                     forecaster=f, **overrides)
             for f in forecasters for p in policies]
    sim = make_batch_simulator(ctrls, cfg)
    shape = (len(forecasters), len(policies))

    def run(rates):
        out = sim(jnp.asarray(rates))                 # [F*P, W, M]
        return jax.tree.map(
            lambda a: a.reshape(shape + a.shape[1:]), out)

    return run


def _canon_static(v):
    """Canonical hashable form of a static hyperparameter value: jit
    static-arg cache keys and artifact JSON must agree on it. Ints stay
    ints — factories index/`arange` with keys like `horizon_min`."""
    if isinstance(v, (bool, str)):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    return v


def _validate_hyper(sp, keys, what: str) -> None:
    bad = set(keys) - set(sp.defaults)
    if bad:
        raise TypeError(f"policy {sp.name!r} has no hyperparameters "
                        f"{sorted(bad)} ({what}); "
                        f"accepts {sorted(sp.defaults)}")


def grid_split(name: str, grid: Sequence[dict], fixed: dict):
    """Validate a hyperparameter grid and split it into traced
    stackables vs static keys.

    Every grid point must set the same keys, all drawn from the policy's
    accepted hyperparameters (a typo'd key raises the same clean
    TypeError `registry.get_controller` gives, not an opaque factory
    error deep inside vmap tracing). Keys in the family's `stackable`
    tuple are *traced* — stacked into f32 arrays and vmapped as fused
    lanes; everything else is *static* — it changes compiled structure
    (buffer lengths, reclassify cadence), so points are grouped by their
    static values and each group compiles once.

    Returns (spec, traced_keys, groups) with groups an ordered list of
    (static_items, grid_indices) preserving first-appearance order.
    """
    sp = registry.spec(name)
    if not grid:
        raise ValueError("empty hyperparameter grid")
    _validate_hyper(sp, fixed, "fixed kwargs")
    keys = sorted(grid[0])
    _validate_hyper(sp, keys, "grid keys")
    overlap = set(keys) & set(fixed)
    if overlap:
        raise TypeError(f"grid key(s) {sorted(overlap)} for policy "
                        f"{name!r} are also passed as fixed kwargs")
    for g in grid:
        if sorted(g) != keys:
            raise ValueError("every grid point must set the same keys")
    traced = tuple(k for k in keys if k in sp.stackable)
    static = tuple(k for k in keys if k not in sp.stackable)
    groups: dict[tuple, list[int]] = {}
    order: list[tuple] = []
    for i, g in enumerate(grid):
        skey = tuple((k, _canon_static(g[k])) for k in static)
        if skey not in groups:
            groups[skey] = []
            order.append(skey)
        groups[skey].append(i)
    return sp, traced, [(skey, tuple(groups[skey])) for skey in order]


def _grid_factory(sp, cfg, classify, fixed):
    """(traced hyper dict, static hyper dict) -> Controller, with the
    registry defaults + `fixed` underneath — the one place grid lanes
    build controllers, shared by the MinuteOut and metrics paths."""
    def build(hyper, static_kw):
        kw = dict(sp.defaults)
        kw.update(fixed)
        kw.update(static_kw)
        kw.update(hyper)       # traced per-lane scalars
        if sp.needs_classifier:
            return sp.factory(cfg, classify or registry.default_classify,
                              **kw)
        return sp.factory(cfg, **kw)
    return build


def _stack_traced(grid: Sequence[dict], idxs, traced) -> dict:
    return {k: jnp.asarray([float(grid[i][k]) for i in idxs], jnp.float32)
            for k in traced}


def _stitch(parts, order):
    """Concatenate per-group [Gk, ...] pytrees back into grid order."""
    cat = (parts[0] if len(parts) == 1
           else jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *parts))
    perm = np.argsort(np.asarray(order, np.int64), kind="stable")
    if (perm == np.arange(perm.size)).all():
        return cat
    return jax.tree.map(lambda a: a[perm], cat)


def make_grid_simulator(name: str, grid: Sequence[dict],
                        cfg: SimConfig = SimConfig(), *,
                        classify=None, **fixed):
    """One policy family, a grid of hyperparameter points, few compiles.

    `grid` is a list of dicts over the family's accepted hyperparameters;
    every point must set the same keys (`fixed` pins the rest). Stackable
    keys are traced f32 lanes under one vmap; static keys
    (`horizon_min`, `stride_min`, `stabilization_min`, ...) group the
    grid and compile once per static group. Returns a fn
    rates [W, M] -> MinuteOut [len(grid), W, M] (grid order preserved);
    its `_cache_size()` reports the compile count for the one-compile-
    per-static-group pin.
    """
    _, traced, groups = grid_split(name, grid, fixed)
    sp = registry.spec(name)
    build = _grid_factory(sp, cfg, classify, fixed)
    grid = [dict(g) for g in grid]

    def run_group(lane_ids, stacked, rates, static_kw):
        def sim_one(_, hyper, r):
            return simulate(r, build(hyper, dict(static_kw)), cfg)
        over_w = jax.vmap(sim_one, in_axes=(None, None, 0))
        return jax.vmap(over_w, in_axes=(0, 0, None))(
            lane_ids, stacked, rates)

    run_group = jax.jit(run_group, static_argnums=(3,))

    def run(rates):
        rates = jnp.asarray(rates, jnp.float32)
        parts, order = [], []
        for skey, idxs in groups:
            parts.append(run_group(jnp.arange(len(idxs)),
                                   _stack_traced(grid, idxs, traced),
                                   rates, skey))
            order.extend(idxs)
        return _stitch(parts, order)

    run._cache_size = run_group._cache_size
    return run


def make_grid_evaluator(name: str, cfg: SimConfig = SimConfig(), *,
                        classify=None, bins: int | None = None,
                        rei_kw: dict | None = None, **fixed):
    """Fused candidate scoring: grid lanes carry `repro.evals.metrics`
    accumulators *inside* the scan and come back as pooled
    EpisodeMetrics + REI per candidate — a [G, W, M] MinuteOut tensor
    never materializes, so scoring 10^3+ candidates is O(G * bins)
    memory. This is the evaluation core of ``repro.tuning``.

    Returns ``evaluate(grid, rates [W, M]) -> (EpisodeMetrics [G],
    REIBreakdown [G])``. The grid is passed per call (search strategies
    re-propose candidates every round); the compiled group body is
    shared across calls, so a search whose rounds keep candidate counts
    constant compiles once per static group total (`_cache_size()` pins
    it). REI baselines default from the episode shape; `rei_kw`
    overrides (e.g. paper-constant baselines).
    """
    # lazy: repro.evals.matrix imports this module at package init
    from repro.evals import metrics as EM
    from repro.evals import rei as ER
    sp = registry.spec(name)
    _validate_hyper(sp, fixed, "fixed kwargs")
    build = _grid_factory(sp, cfg, classify, fixed)
    bins = EM.DEFAULT_BINS if bins is None else bins
    edges = EM.response_edges(bins, cfg.resp_cap_sec)
    rei_kw = dict(rei_kw or {})

    def eval_group(lane_ids, stacked, rates, static_kw):
        W, _ = rates.shape

        def eval_one(_, hyper):
            ctrl = build(hyper, dict(static_kw))
            st0 = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (W,) + jnp.shape(a)),
                initial_state(ctrl, cfg))
            idx0 = jnp.zeros((W,), jnp.int32)

            def one_lane(s, i, r):
                (s2, i2), m = minute_step(cfg, ctrl, (s, i), r)
                return s2, i2, m

            def body(carry, rate_w):
                st, idx, acc = carry
                st, idx, m = jax.vmap(one_lane)(st, idx, rate_w)
                return (st, idx,
                        EM.accum_update_pooled(acc, m, edges)), None

            (_, _, acc), _ = jax.lax.scan(
                body, (st0, idx0, EM.accum_init(bins)), rates.T)
            return acc

        return jax.vmap(eval_one)(lane_ids, stacked)

    eval_group = jax.jit(eval_group, static_argnums=(3,))

    def evaluate(grid, rates):
        _, traced, groups = grid_split(name, grid, fixed)
        grid = [dict(g) for g in grid]
        rates = jnp.asarray(rates, jnp.float32)
        W, M = rates.shape
        parts, order = [], []
        for skey, idxs in groups:
            parts.append(eval_group(jnp.arange(len(idxs)),
                                    _stack_traced(grid, idxs, traced),
                                    rates, skey))
            order.extend(idxs)
        met = EM.finalize(_stitch(parts, order), edges)
        rb = ER.rei(met.slo_violation_rate, met.replica_minutes,
                    met.scaling_actions,
                    **{"minutes": M, "n_workloads": W, **rei_kw})
        return met, rb

    evaluate._cache_size = eval_group._cache_size
    return evaluate
