"""Batched multi-policy simulation: policies x workloads in ONE compile.

``make_simulator`` (one policy, vmapped workloads) compiles one scan per
policy — benchmarks that sweep policies pay the XLA compile N times and
dispatch N times. This module folds the policy axis into the same
compiled scan:

* `make_batch_simulator(controllers, cfg)` — arbitrary (heterogeneous)
  controllers. Every controller's state is carried in a tuple slot and
  evolves exactly as it would standalone; a per-lane policy index selects
  whose decision drives the plant. `jit(vmap(vmap(simulate)))` over
  policies x workloads: one scan, one dispatch. Lane p's trajectory is
  bit-for-bit the trajectory of controller p alone (the parity test in
  tests/test_scaling.py pins this). Trade-off: every lane evaluates all
  P `decide`s (O(P^2) controller flops for one compile + one dispatch) —
  the plant dynamics dominate and P is single-digit, but for large
  homogeneous sweeps prefer `make_grid_simulator`, which has no
  duplicated work.

* `make_grid_simulator(name, grid, cfg)` — same-structured controllers
  (one registry family, hyperparameters declared `stackable`). The
  hyperparameters are stacked into arrays and the *factory itself* is
  traced with per-lane scalars, so no per-slot state duplication at all.
  This is the cheap path for hyperparameter sweeps (target CPU, panic
  thresholds, guardrail fractions...).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.scaling import registry
from repro.scaling.api import Controller
from repro.sim.cluster import MinuteOut, SimConfig, simulate


def stack_controllers(controllers: Sequence[Controller],
                      policy_idx) -> Controller:
    """One Controller carrying every component's state; `policy_idx`
    (a traced scalar) selects whose desired/cooldown drive the plant.
    Component states evolve independently, so the selected lane's
    dynamics are identical to running that controller alone."""
    ctrls = list(controllers)

    def init():
        return tuple(c.init() for c in ctrls)

    def on_minute(state, hist, minute_idx):
        return tuple(c.on_minute(s, hist, minute_idx)
                     for c, s in zip(ctrls, state))

    def decide(state, obs):
        outs = [c.decide(s, obs) for c, s in zip(ctrls, state)]
        new_state = tuple(o[0] for o in outs)
        desired = jnp.stack(
            [jnp.asarray(o[1], jnp.float32) for o in outs])[policy_idx]
        cool = jnp.stack(
            [jnp.asarray(o[2], jnp.float32) for o in outs])[policy_idx]
        return new_state, desired, cool

    name = "batch[" + ",".join(c.name for c in ctrls) + "]"
    return Controller(name, init, on_minute, decide)


def make_batch_simulator(controllers: Sequence[Controller],
                         cfg: SimConfig = SimConfig()):
    """jit(vmap(vmap(simulate))): rates [W, M] -> MinuteOut [P, W, M]."""
    ctrls = list(controllers)

    def sim_one(idx, rates):
        return simulate(rates, stack_controllers(ctrls, idx), cfg)

    over_workloads = jax.vmap(sim_one, in_axes=(None, 0))
    over_policies = jax.vmap(over_workloads, in_axes=(0, None))
    idxs = jnp.arange(len(ctrls), dtype=jnp.int32)
    return jax.jit(lambda rates: over_policies(
        idxs, rates.astype(jnp.float32)))


def batch_simulate(controllers: Sequence[Controller], rates,
                   cfg: SimConfig = SimConfig()) -> MinuteOut:
    """Convenience wrapper: rates [W, M] -> MinuteOut of [P, W, M]."""
    return make_batch_simulator(controllers, cfg)(jnp.asarray(rates))


def make_forecast_batch_simulator(policies: Sequence[str],
                                  forecasters: Sequence,
                                  cfg: SimConfig = SimConfig(), *,
                                  classify=None, **overrides):
    """Forecasters x policies x workloads in ONE compiled scan.

    Every policy must be forecaster-aware (`takes_forecaster` in its
    registry spec: `predictive`, `aapa`, `hybrid`); `forecasters` are
    ``repro.forecast.registry`` names or Forecaster instances. Returns a
    fn rates [W, M] -> MinuteOut [F, P, W, M]; lane (f, p) is bit-for-bit
    the standalone simulation of policy p using forecaster f (pinned by
    tests/test_forecast.py)."""
    aware = [n for n in registry.available()
             if registry.spec(n).takes_forecaster]
    for p in policies:
        if not registry.spec(p).takes_forecaster:
            raise TypeError(f"policy {p!r} takes no forecaster; "
                            f"forecaster-aware policies: {aware}")
    ctrls = [registry.get_controller(p, cfg, classify=classify,
                                     forecaster=f, **overrides)
             for f in forecasters for p in policies]
    sim = make_batch_simulator(ctrls, cfg)
    shape = (len(forecasters), len(policies))

    def run(rates):
        out = sim(jnp.asarray(rates))                 # [F*P, W, M]
        return jax.tree.map(
            lambda a: a.reshape(shape + a.shape[1:]), out)

    return run


def make_grid_simulator(name: str, grid: Sequence[dict],
                        cfg: SimConfig = SimConfig(), *,
                        classify=None, **fixed):
    """One policy family, a grid of hyperparameter points, one compile.

    `grid` is a list of dicts over the family's `stackable` keys; every
    point must set the same keys. Returns a jitted fn
    rates [W, M] -> MinuteOut [len(grid), W, M].
    """
    sp = registry.spec(name)
    if not grid:
        raise ValueError("empty hyperparameter grid")
    keys = sorted(grid[0])
    bad = set(keys) - set(sp.stackable)
    if bad:
        raise TypeError(f"policy {name!r} cannot stack {sorted(bad)}; "
                        f"stackable: {sorted(sp.stackable)}")
    for g in grid:
        if sorted(g) != keys:
            raise ValueError("every grid point must set the same keys")
    stacked = {k: jnp.asarray([float(g[k]) for g in grid], jnp.float32)
               for k in keys}

    def sim_one(hyper, rates):
        kw = dict(sp.defaults)
        kw.update(fixed)
        kw.update(hyper)       # traced per-lane scalars
        if sp.needs_classifier:
            ctrl = sp.factory(cfg, classify or registry.default_classify,
                              **kw)
        else:
            ctrl = sp.factory(cfg, **kw)
        return simulate(rates, ctrl, cfg)

    over_workloads = jax.vmap(sim_one, in_axes=(None, 0))
    over_grid = jax.vmap(over_workloads, in_axes=(0, None))
    return jax.jit(lambda rates: over_grid(
        stacked, jnp.asarray(rates, jnp.float32)))
