"""Named controller factories with per-policy default hyperparameters.

    from repro.scaling import registry
    ctrl = registry.get_controller("hpa", SimConfig(), target=0.6)

Benchmarks, examples, and the serving launcher all resolve policies here,
so adding a policy is one `register(...)` call (see README "add your own
controller"). Each spec also declares which hyperparameters are
*stackable* — safe to pass as traced jnp scalars — which
``repro.scaling.batch`` uses to vmap one compiled simulation over a
hyperparameter grid.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from repro.scaling import policies as P
from repro.scaling.api import Controller


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    name: str
    factory: Callable[..., Controller]   # factory(cfg, **hyper) -> Controller
    defaults: dict[str, Any]
    stackable: tuple[str, ...] = ()      # kwargs that may be traced arrays
    needs_classifier: bool = False
    takes_forecaster: bool = False       # accepts forecaster= by name
    description: str = ""


_REGISTRY: dict[str, PolicySpec] = {}


def register(name: str, factory: Callable[..., Controller], *,
             defaults: dict[str, Any] | None = None,
             stackable: tuple[str, ...] = (),
             needs_classifier: bool = False,
             takes_forecaster: bool = False,
             description: str = "") -> None:
    if name in _REGISTRY:
        raise ValueError(f"policy {name!r} already registered")
    _REGISTRY[name] = PolicySpec(name, factory, dict(defaults or {}),
                                 stackable, needs_classifier,
                                 takes_forecaster, description)


def available() -> list[str]:
    return sorted(_REGISTRY)


#: ``registry.make("tuned:<policy>@<hash12>", cfg)`` rebuilds the winner
#: of a published ``repro.tuning`` search card exactly.
TUNED_PREFIX = "tuned:"


def _resolve_tuned(name: str) -> tuple[str, dict[str, Any]]:
    from repro.tuning import artifacts as tuning_artifacts
    return tuning_artifacts.resolve(name[len(TUNED_PREFIX):])


def spec(name: str) -> PolicySpec:
    if name.startswith(TUNED_PREFIX):
        return spec(_resolve_tuned(name)[0])
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; "
                       f"available: {available()}") from None


def default_classify(feats):
    """Fallback classifier for aapa-family policies when no trained model
    is supplied: STATIONARY_NOISY at 0.5 confidence, i.e. Algorithm 1's
    conservative midpoint. Real runs pass `trained.make_classify()`."""
    return jnp.int32(2), jnp.float32(0.5)


def get_controller(name: str, cfg, *, classify=None,
                   **overrides) -> Controller:
    """Build a registered controller with defaults + overrides applied.

    ``tuned:<policy>@<hash12>`` names resolve through the content-
    addressed tuning cards (``repro.tuning.artifacts``): the card's best
    point is applied over the base policy's defaults, then `overrides` on
    top — bit-identical to the controller the search scored."""
    if name.startswith(TUNED_PREFIX):
        base, params = _resolve_tuned(name)
        return get_controller(base, cfg, classify=classify,
                              **{**params, **overrides})
    sp = spec(name)
    kw = dict(sp.defaults)
    unknown = set(overrides) - set(kw)
    if unknown:
        raise TypeError(f"policy {name!r} has no hyperparameters "
                        f"{sorted(unknown)}; accepts {sorted(kw)}")
    kw.update(overrides)
    if sp.needs_classifier:
        return sp.factory(cfg, classify or default_classify, **kw)
    return sp.factory(cfg, **kw)


#: Canonical spelling for new code: ``registry.make("aapa", cfg, ...)``.
make = get_controller


# ------------------------------------------------------ built-in catalog ----
register(
    "hpa", P.hpa_controller,
    defaults=dict(target=0.70, stabilization_min=5.0, cooldown_min=5.0,
                  tolerance=0.10),
    stackable=("target", "cooldown_min", "tolerance"),
    description="Kubernetes HPA: reactive CPU-target scaling with "
                "downscale stabilization (paper §IV.C baseline).")

register(
    "predictive", P.predictive_controller,
    defaults=dict(target=0.70, horizon_min=15, cooldown_min=5.0,
                  forecaster="holt_winters", band=None,
                  conservative=False),
    stackable=("target", "cooldown_min"),
    takes_forecaster=True,
    description="Generic predictive over any repro.forecast registry "
                "model (default Holt-Winters, 15-minute horizon — the "
                "paper §IV.C baseline).")

register(
    "aapa", P.aapa_controller,
    defaults=dict(stride_min=10, horizon_min=15,
                  forecaster="holt_winters", band=None,
                  forecast_confidence=None),
    needs_classifier=True,
    takes_forecaster=True,
    description="Archetype-aware predictive autoscaler with uncertainty "
                "quantification (the paper's system, §III); confidence = "
                "classifier x forecast-interval signal.")

register(
    "kpa", P.kpa_controller,
    defaults=dict(target_concurrency=None, panic_threshold=2.0,
                  stable_window_s=60.0, panic_window_s=6.0,
                  cooldown_min=1.0),
    stackable=("panic_threshold",),
    description="Knative-KPA-style concurrency scaler with stable/panic "
                "windows.")

register(
    "hybrid", P.hybrid_controller,
    defaults=dict(guard_target=0.85, max_down_frac=0.3, stride_min=10,
                  horizon_min=15, forecaster="holt_winters", band=None,
                  forecast_confidence=None),
    stackable=("guard_target", "max_down_frac"),
    needs_classifier=True,
    takes_forecaster=True,
    description="AAPA with a reactive guardrail floor and bounded "
                "scale-down steps.")
