"""Drive a Python-loop `ServingEngine` with any `scaling.api` Controller.

The engine is just another plant: the adapter builds an `Obs` from live
engine state (ready/starting replicas, active decode slots, queue depth,
a sliding-window arrival rate), runs the controller's jittable closures
*eagerly*, applies the shared cooldown semantics (`api.apply_decision` —
the very code the simulator compiles), and calls `engine.scale_to`.

Time mapping: serving demos compress time ("one logical minute" of trace
= `minute_s` engine-seconds). The adapter works in logical units
throughout; `sim_config_for_engine` derives a `SimConfig` whose capacity
and latency fields describe the engine in those units, so one policy +
one hyperparameter set behaves consistently across both backends.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.obs import trace as obs_trace
from repro.scaling.api import Controller, Obs, apply_decision, limiter_init
from repro.sim.cluster import SimConfig


def sim_config_for_engine(engine, *, minute_s: float = 60.0,
                          service_s: float | None = None,
                          control_interval_sec: int = 15) -> SimConfig:
    """SimConfig describing `engine` in logical units (1 logical minute =
    `minute_s` engine-seconds). `service_s` is the per-request engine-time
    estimate (defaults to mean gen_len x step_time unavailable up front,
    so a 0.4 s serving default)."""
    service_engine = 0.4 if service_s is None else float(service_s)
    to_logical = 60.0 / minute_s              # engine-sec -> logical-sec
    return SimConfig(
        startup_sec=max(int(round(engine.startup_s * to_logical)), 1),
        control_interval_sec=control_interval_sec,
        rps_per_replica=engine.lanes / (service_engine * to_logical),
        service_sec=service_engine * to_logical,
        slo_sec=engine.slo_s * to_logical,
        max_replicas=float(engine.max_replicas),
        initial_replicas=float(engine.ready_replicas))


class EngineAutoscaler:
    """Feeds `engine.scale_to` from a Controller once per control
    interval; call `on_tick()` after every `engine.step()`."""

    def __init__(self, engine, controller: Controller,
                 cfg: SimConfig | None = None, *,
                 minute_s: float = 60.0):
        self.engine = engine
        self.controller = controller
        self.cfg = cfg or sim_config_for_engine(engine, minute_s=minute_s)
        self.minute_s = float(minute_s)
        self._sec_per_logical = self.minute_s / 60.0

        self.ctrl_state = controller.init()
        self.lim = limiter_init()
        self.history = np.zeros(self.cfg.history_len, np.float32)
        self.util_ema = 0.5
        self.minute_idx = 0
        self._arrivals_seen = 0
        self._ctrl_every = (self.cfg.control_interval_sec
                            * self._sec_per_logical)
        self._next_ctrl = 0.0
        self._last_ctrl_t = 0.0
        self.last_desired = float(engine.ready_replicas)
        self.last_cooldown_s = 0.0     # logical seconds, last decide()
        # one DecisionRecord per _control, same schema as the in-scan
        # sim trace (repro.obs.trace), so engine runs are diffable
        # against simulation runs of the same policy
        self.decisions: list[obs_trace.DecisionRecord] = []

    @classmethod
    def from_policy(cls, engine, policy: str, *, classify=None,
                    forecaster=None, minute_s: float = 60.0,
                    cfg: SimConfig | None = None,
                    **overrides) -> "EngineAutoscaler":
        """Resolve `policy` (and optionally a ``repro.forecast`` registry
        `forecaster` name) through ``repro.scaling.registry`` against a
        SimConfig derived from the engine — the one-liner the serving
        demos use."""
        from repro.scaling import registry
        cfg = cfg or sim_config_for_engine(engine, minute_s=minute_s)
        if forecaster is not None:
            overrides["forecaster"] = forecaster
        ctrl = registry.get_controller(policy, cfg, classify=classify,
                                       **overrides)
        return cls(engine, ctrl, cfg, minute_s=minute_s)

    # ------------------------------------------------------------ sensing
    def _observe(self) -> Obs:
        eng = self.engine
        total = eng.ready_replicas + len(eng.starting)
        lanes = eng.ready_replicas * eng.lanes
        # clamp: draining slots on just-removed replicas would otherwise
        # read as >100% — a value the simulator's util can never produce
        util_inst = min(len(eng.active) / max(lanes, 1), 1.0)
        # 1-logical-minute aggregation, updated per control step
        alpha = min(self.cfg.control_interval_sec
                    / self.cfg.metric_tau_sec, 1.0)
        self.util_ema += alpha * (util_inst - self.util_ema)
        rate_engine = eng.observed_rate(window_s=self.minute_s)
        rate_logical = rate_engine * self._sec_per_logical
        return Obs(ready_total=jnp.float32(total),
                   ready=jnp.float32(eng.ready_replicas),
                   util_ema=jnp.float32(self.util_ema),
                   queue=jnp.float32(len(eng.queue)),
                   rate_rps=jnp.float32(rate_logical),
                   rate_history=jnp.asarray(self.history),
                   minute_idx=jnp.int32(self.minute_idx))

    # ------------------------------------------------------------ control
    def on_tick(self) -> None:
        t = self.engine.t
        while t >= (self.minute_idx + 1) * self.minute_s:
            self._on_minute()
        if t >= self._next_ctrl:
            # anchored schedule: engine steps that overshoot the control
            # time don't stretch the interval (and so the cooldown clock)
            self._next_ctrl += self._ctrl_every
            if self._next_ctrl <= t:
                self._next_ctrl = t + self._ctrl_every
            self._control(t)

    def _on_minute(self) -> None:
        arrived = self.engine.arrivals_total - self._arrivals_seen
        self._arrivals_seen = self.engine.arrivals_total
        self.history = np.roll(self.history, -1)
        self.history[-1] = float(arrived)
        self.minute_idx += 1
        self.ctrl_state = self.controller.on_minute(
            self.ctrl_state, jnp.asarray(self.history),
            jnp.int32(self.minute_idx))

    def _control(self, now: float) -> None:
        eng = self.engine
        obs = self._observe()
        pre_state = self.ctrl_state
        self.ctrl_state, desired_raw, cool = self.controller.decide(
            pre_state, obs)
        desired = jnp.clip(desired_raw, 0.0, self.cfg.max_replicas)
        total = jnp.float32(eng.ready_replicas + len(eng.starting))
        # cooldown decays by real elapsed time, in logical seconds
        dt_logical = (now - self._last_ctrl_t) / self._sec_per_logical
        self._last_ctrl_t = now
        cooldown_before = self.lim.cooldown
        self.lim, act = apply_decision(
            self.lim, total, desired, cool, jnp.bool_(True),
            dt=float(dt_logical))
        target = float(total) + float(act.add) - float(act.remove)
        self.last_desired = float(desired)
        self.last_cooldown_s = float(cool)
        exp = (self.controller.explain(pre_state, obs)
               if getattr(self.controller, "explain", None) is not None
               else obs_trace.explain_nan())
        self.decisions.append(obs_trace.record(
            self.cfg, minute_idx=self.minute_idx,
            sec=now / self._sec_per_logical - 60.0 * self.minute_idx,
            ready=obs.ready, total=total, queue=obs.queue,
            util_ema=obs.util_ema, rate_rps=obs.rate_rps, exp=exp,
            desired_raw=desired_raw, desired=desired, cooldown_req=cool,
            cooldown_before=cooldown_before, act=act))
        eng.scale_to(int(round(target)))

    def decision_trace(self) -> obs_trace.DecisionRecord:
        """The decision log as one DecisionRecord of [N] numpy arrays."""
        return obs_trace.stack_records(self.decisions)


def run_autoscaled(engine, controller: Controller, *, submit_fn,
                   n_steps: int, cfg: SimConfig | None = None,
                   minute_s: float = 60.0
                   ) -> tuple[dict, "obs_trace.DecisionRecord"]:
    """Convenience loop: `submit_fn(step_idx, engine)` enqueues arrivals,
    then the engine steps and the autoscaler reacts. Returns
    `(engine.summary(), decision trace)` — the trace is the stacked
    [N]-array DecisionRecord log, so demos can print why they scaled."""
    auto = EngineAutoscaler(engine, controller, cfg, minute_s=minute_s)
    for i in range(n_steps):
        submit_fn(i, engine)
        engine.step()
        auto.on_tick()
    return engine.summary(), auto.decision_trace()
