"""Scenario library: named workload x plant configurations for policy
evaluation, so REI / SLO trade-off curves come from one API.

A `Scenario` bundles a rate matrix [workloads, minutes] with the
`SimConfig` it should run under. Builders cover archetype-pure mixes,
burst storms, diurnal+ramp composites, and plant-parameter sweeps
(startup latency, `rps_per_replica`):

    from repro.scaling import scenarios, batch, registry
    sc = scenarios.get("burst_storm", n_workloads=8, seed=3)
    out = batch.batch_simulate(ctrls, sc.rates, sc.cfg)   # [P, W, M]

Everything is seeded numpy; nothing here traces or compiles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import numpy as np

from repro.core.archetypes import Archetype
from repro.data.azure_synth import generate_traces
from repro.sim.cluster import SimConfig


class Scenario(NamedTuple):
    name: str
    rates: np.ndarray        # [W, M] arrivals per minute
    cfg: SimConfig
    meta: dict


_BUILDERS: dict[str, Callable[..., Scenario]] = {}


def register(name: str):
    def deco(fn):
        _BUILDERS[name] = fn
        return fn
    return deco


def available() -> list[str]:
    return sorted(_BUILDERS)


def get(name: str, **kw) -> Scenario:
    try:
        builder = _BUILDERS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {available()}") from None
    return builder(**kw)


# ------------------------------------------------------- archetype mixes ----
def _pure_counts(kind: Archetype, n: int, minutes: int, seed: int):
    """n archetype-pure traces via the calibrated Azure-like generators."""
    n_days = max(-(-minutes // 1440), 1)
    traces = generate_traces(n_functions=n, n_days=n_days, seed=seed,
                             mix={kind: 1.0})
    return traces.counts[:, :minutes]


@register("archetype_pure")
def archetype_pure(kind: str = "SPIKE", n_workloads: int = 16,
                   minutes: int = 1440, seed: int = 0,
                   cfg: SimConfig = SimConfig()) -> Scenario:
    arch = Archetype[kind]
    rates = _pure_counts(arch, n_workloads, minutes, seed)
    return Scenario(f"archetype_pure:{kind}", rates, cfg,
                    {"kind": kind, "seed": seed})


@register("archetype_mix")
def archetype_mix(n_workloads: int = 32, minutes: int = 1440,
                  seed: int = 0, cfg: SimConfig = SimConfig()) -> Scenario:
    """Default paper mix (PERIODIC-heavy, §V.A marginals)."""
    n_days = max(-(-minutes // 1440), 1)
    traces = generate_traces(n_functions=n_workloads, n_days=n_days,
                             seed=seed)
    return Scenario("archetype_mix", traces.counts[:, :minutes], cfg,
                    {"pattern": traces.pattern.tolist(), "seed": seed})


# ----------------------------------------------------------- composites ----
@register("burst_storm")
def burst_storm(n_workloads: int = 16, minutes: int = 720, seed: int = 0,
                floor: float = 30.0, height: float = 6000.0,
                n_storms: int = 3,
                cfg: SimConfig = SimConfig()) -> Scenario:
    """Synchronized bursts: every workload spikes in the same windows
    (correlated incident traffic — the hardest case for reactive scaling
    and the regime where SPIKE warm pools pay off)."""
    rng = np.random.default_rng(seed)
    rates = np.full((n_workloads, minutes), floor, np.float32)
    lo = max(minutes // 6, 1)
    hi = max(minutes - max(minutes // 6, 15), lo + 1)
    starts = rng.integers(lo, hi, size=n_storms)
    for s in starts:
        dur = int(rng.integers(3, 10))
        decay = np.exp(-np.arange(dur) / max(dur / 3.0, 1.0))
        amp = height * rng.uniform(0.5, 1.5, size=(n_workloads, 1))
        end = min(s + dur, minutes)
        rates[:, s:end] += amp * decay[None, :end - s]
    counts = rng.poisson(rates).astype(np.float32)
    return Scenario("burst_storm", counts, cfg,
                    {"storm_starts": sorted(int(s) for s in starts)})


@register("diurnal_ramp")
def diurnal_ramp(n_workloads: int = 16, minutes: int = 2880,
                 seed: int = 0, base: float = 1200.0,
                 growth: float = 2.0,
                 cfg: SimConfig = SimConfig()) -> Scenario:
    """Diurnal sinusoid composed with a multi-day linear ramp (organic
    growth): PERIODIC and RAMP evidence in the same window, probing
    classification ambiguity."""
    rng = np.random.default_rng(seed)
    t = np.arange(minutes, dtype=np.float64)
    day = 1.0 + 0.6 * np.sin(2 * np.pi * t / 1440.0
                             - 0.5 * np.pi)          # trough at t=0
    ramp = 1.0 + (growth - 1.0) * t / max(minutes - 1, 1)
    phase = rng.uniform(0, 2 * np.pi, size=(n_workloads, 1))
    jitter = 1.0 + 0.1 * np.sin(2 * np.pi * t[None, :] / 360.0 + phase)
    rates = base * day[None, :] * ramp[None, :] * jitter
    counts = rng.poisson(np.maximum(rates, 0.0)).astype(np.float32)
    return Scenario("diurnal_ramp", counts, cfg,
                    {"base": base, "growth": growth})


@register("idle_wake")
def idle_wake(n_workloads: int = 8, minutes: int = 360, seed: int = 0,
              burst: float = 600.0,
              cfg: SimConfig = SimConfig()) -> Scenario:
    """Long idle stretch then a burst: exercises scale-to-zero, the
    activator path, and cold-start accounting on both backends."""
    rng = np.random.default_rng(seed)
    rates = np.zeros((n_workloads, minutes), np.float32)
    wake = minutes - minutes // 4
    rates[:, wake:wake + 5] = burst
    counts = rng.poisson(rates).astype(np.float32)
    return Scenario("idle_wake", counts, cfg, {"wake_minute": int(wake)})


# --------------------------------------------------------- plant sweeps ----
def startup_sweep(values=(5, 15, 30, 60, 120), base: str = "burst_storm",
                  **kw) -> list[Scenario]:
    """The same workloads under increasing pod startup latency — the REI
    vs cold-start trade-off curve's x-axis."""
    out = []
    for v in values:
        sc = get(base, **kw)
        cfg = dataclasses.replace(sc.cfg, startup_sec=int(v))
        out.append(Scenario(f"{sc.name}@startup={v}s", sc.rates, cfg,
                            {**sc.meta, "startup_sec": int(v)}))
    return out


def rps_per_replica_sweep(values=(5.0, 10.0, 20.0, 40.0),
                          base: str = "archetype_mix",
                          **kw) -> list[Scenario]:
    """Replica capacity sweep: smaller `rps_per_replica` means more
    replicas per unit load (finer-grained scaling, more churn)."""
    out = []
    for v in values:
        sc = get(base, **kw)
        cfg = dataclasses.replace(sc.cfg, rps_per_replica=float(v))
        out.append(Scenario(f"{sc.name}@rps={v}", sc.rates, cfg,
                            {**sc.meta, "rps_per_replica": float(v)}))
    return out
