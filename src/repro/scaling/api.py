"""Backend-agnostic autoscaling control-plane protocol.

One Controller API serves every plant that can produce an `Obs`: the
jittable cluster simulator (`repro.sim.cluster`, lax.scan over ticks) and
the Python-loop serving engine (`repro.serve.engine` via
`repro.scaling.adapter`). A controller is three pure functions:

    init()                               -> ctrl_state
    on_minute(ctrl_state, rate_history, minute_idx) -> ctrl_state
    decide(ctrl_state, obs) -> (ctrl_state, desired_replicas, cooldown_sec)

All functions must be jittable: the simulator traces them inside nested
scans, the serving adapter calls the very same closures eagerly. Policies
therefore never branch in Python on observation values.

Scale-down stabilization (cooldown) is plant-independent semantics and
lives here too: `apply_decision` turns a raw `decide` output into an
add/remove action under the cooldown rules every backend shares —
scale-ups apply immediately, scale-downs only once the cooldown requested
by the *previous* scale-down has expired.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Obs(NamedTuple):
    """What a controller sees at a control step."""
    ready_total: jax.Array   # ready + starting replicas
    ready: jax.Array         # ready replicas only
    util_ema: jax.Array      # 1-min aggregated CPU utilization
    queue: jax.Array         # queued requests
    rate_rps: jax.Array      # current arrival rate (req/s)
    rate_history: jax.Array  # [history_len] per-minute counts (old->new)
    minute_idx: jax.Array    # int32 global minute


class Controller(NamedTuple):
    """Pluggable autoscaling policy (all functions jittable)."""
    name: str
    init: Callable[[], Any]                      # -> ctrl_state
    on_minute: Callable[[Any, jax.Array, jax.Array], Any]
    # (ctrl_state, rate_history, minute_idx) -> ctrl_state
    decide: Callable[[Any, "Obs"], tuple[Any, jax.Array, jax.Array]]
    # (ctrl_state, obs) -> (ctrl_state, desired_replicas, cooldown_sec)
    explain: Callable[[Any, "Obs"], Any] | None = None
    # optional telemetry hook: (PRE-decide ctrl_state, obs) ->
    # repro.obs.trace.ExplainOut — the forecast/confidence/guardrail
    # signals behind the decision `decide` is about to make. Pure and
    # jittable like decide; None means "no signals" (NaN-filled record).


# ----------------------------------------------- cooldown / stabilization ----
class LimiterState(NamedTuple):
    """Scale-down rate-limiter state shared by every backend."""
    cooldown: jax.Array      # seconds until the next scale-down is allowed
    last_dir: jax.Array      # +1 / -1 / 0 last scaling direction


class ScaleAction(NamedTuple):
    add: jax.Array           # replicas to start now
    remove: jax.Array        # replicas to remove now
    scale_up: jax.Array      # bool
    scale_down: jax.Array    # bool
    oscillation: jax.Array   # f32 1.0 when direction flipped


def limiter_init() -> LimiterState:
    return LimiterState(cooldown=jnp.float32(0.0),
                        last_dir=jnp.float32(0.0))


def apply_decision(lim: LimiterState, total: jax.Array,
                   desired: jax.Array, cooldown_req: jax.Array,
                   do_ctrl: jax.Array,
                   dt: float | jax.Array = 1.0
                   ) -> tuple[LimiterState, ScaleAction]:
    """Shared scaling semantics: compare `desired` against the current
    `total` (ready + starting), honor the scale-down cooldown, and track
    direction flips (the oscillation metric). `do_ctrl` masks off-interval
    ticks; `dt` is the wall seconds since the last call."""
    scale_up = do_ctrl & (desired > total + 0.5)
    can_down = lim.cooldown <= 0.0
    scale_down = do_ctrl & (desired < total - 0.5) & can_down

    add = jnp.where(scale_up, desired - total, 0.0)
    remove = jnp.where(scale_down, total - desired, 0.0)

    dir_now = jnp.where(scale_up, 1.0, jnp.where(scale_down, -1.0, 0.0))
    osc = ((dir_now != 0.0) & (lim.last_dir != 0.0)
           & (dir_now != lim.last_dir)).astype(jnp.float32)
    last_dir = jnp.where(dir_now != 0.0, dir_now, lim.last_dir)
    cooldown = jnp.where(scale_down, cooldown_req,
                         jnp.maximum(lim.cooldown - dt, 0.0))

    return (LimiterState(cooldown=cooldown, last_dir=last_dir),
            ScaleAction(add=add, remove=remove, scale_up=scale_up,
                        scale_down=scale_down, oscillation=osc))
