"""Unified autoscaling control plane.

Layout:

* ``api``       — the backend-agnostic Controller/Obs protocol and shared
                  cooldown (scale-down stabilization) semantics.
* ``policies``  — hpa / predictive / aapa / kpa / hybrid controllers.
* ``registry``  — named factories with default hyperparameters:
                  ``get_controller("hpa", cfg, target=0.6)``.
* ``batch``     — policies x workloads in ONE jitted scan
                  (``make_batch_simulator``) + hyperparameter-grid
                  stacking (``make_grid_simulator``).
* ``scenarios`` — named workload/plant configurations and sweeps.
* ``adapter``   — drives the Python-loop ``repro.serve.engine`` with the
                  same controllers.

The cluster simulator (`repro.sim.cluster`) is the jittable plant; the
serving engine (`repro.serve.engine`) is the Python plant. Both consume
exactly this protocol.
"""
from repro.scaling.api import (Controller, LimiterState, Obs,       # noqa: F401
                               ScaleAction, apply_decision, limiter_init)
from repro.scaling.registry import available, get_controller  # noqa: F401
