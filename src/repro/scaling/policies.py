"""Autoscaling policies, all speaking the `repro.scaling.api` protocol.

* ``hpa_controller`` — paper §IV.C baseline: reactive, 70% CPU target,
  5-minute downscale stabilization window, 5-minute scale-down cooldown,
  +-10% tolerance band (Kubernetes semantics).
* ``predictive_controller`` — paper §IV.C baseline: uniform Holt-Winters,
  15-minute prediction horizon, no workload differentiation.
* ``aapa_controller`` — the paper's system (§III.C): every 10 minutes,
  extract 38 features from the last 60 minutes, classify the archetype,
  beta-calibrate the confidence, adjust Table III parameters via
  Algorithm 1, and apply the archetype strategy.
* ``kpa_controller`` — Knative-KPA-style concurrency scaler: stable and
  panic windows over estimated in-flight concurrency, panic mode pins the
  max while active.
* ``hybrid_controller`` — AAPA with a reactive guardrail: the archetype
  strategy never drops below what live utilization requires, and each
  scale-down step is bounded to a fraction of the fleet.

Every controller is fully jittable and backend-agnostic: the same closure
runs compiled inside ``repro.sim.cluster`` and eagerly inside
``repro.scaling.adapter``. The `cfg` argument is duck-typed — anything
with the ``SimConfig`` capacity fields (`rps_per_replica`, `service_sec`,
`initial_replicas`, `control_interval_sec`) works.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import features as F
from repro.core import forecasting as fc
from repro.core import uncertainty
from repro.core.archetypes import table_iii_arrays
from repro.forecast import api as fapi
from repro.forecast import conformal as fconf
from repro.forecast import registry as forecast_registry
from repro.obs.trace import ExplainOut
from repro.scaling.api import Controller, Obs

EPSF = 1e-9


def _nan() -> jax.Array:
    return jnp.float32(jnp.nan)


def _select4(idx, v0, v1, v2, v3):
    """Branch-free 4-way archetype select, bit-exact with ``table[idx]``
    (it returns exactly one of the four values) but lowered as three
    vector selects instead of a lane-dynamic gather — the form the fused
    episode kernel (``repro.kernels.episode_block``) vectorizes."""
    return jnp.where(idx == 0, v0,
                     jnp.where(idx == 1, v1,
                               jnp.where(idx == 2, v2, v3)))


# ---------------------------------------------------------------- HPA ----
class HPAState(NamedTuple):
    desired_buf: jax.Array  # ring buffer of recent desired counts
    last_total: jax.Array


def hpa_controller(cfg, *, target: float = 0.70,
                   stabilization_min: float = 5.0,
                   cooldown_min: float = 5.0,
                   tolerance: float = 0.10) -> Controller:
    buf_len = max(int(stabilization_min * 60 / cfg.control_interval_sec), 1)

    def init():
        return HPAState(
            desired_buf=jnp.full((buf_len,), cfg.initial_replicas,
                                 jnp.float32),
            last_total=jnp.float32(cfg.initial_replicas))

    def on_minute(state, hist, minute_idx):
        return state

    def decide(state: HPAState, obs: Obs):
        ratio = obs.util_ema / target
        in_band = jnp.abs(ratio - 1.0) <= tolerance
        raw = jnp.ceil(obs.ready_total * ratio)
        raw = jnp.where(in_band, obs.ready_total, raw)
        # serverless scale-to-zero on sustained idle (Knative-style KPA);
        # the activator path below wakes the endpoint on traffic.
        idle = ((obs.util_ema < 0.02) & (obs.queue <= 0.0)
                & (obs.rate_rps <= 1e-6))
        raw = jnp.where(idle, 0.0, jnp.maximum(raw, 1.0))
        wake = (obs.rate_rps > 0.0) | (obs.queue > 0.0)
        raw = jnp.where(wake, jnp.maximum(raw, 1.0), raw)
        buf = jnp.concatenate([state.desired_buf[1:], raw[None]])
        # downscale stabilization: never below the window max
        stabilized = jnp.maximum(raw, jnp.max(buf))
        desired = jnp.where(raw >= obs.ready_total, raw, stabilized)
        return (HPAState(buf, desired), desired,
                jnp.float32(cooldown_min * 60.0))

    return Controller("hpa", init, on_minute, decide)


# --------------------------------------------------- Generic Predictive ----
class PredState(NamedTuple):
    fc: fapi.FState


def _resolve_forecaster(forecaster, band):
    """Name or Forecaster -> Forecaster, conformal-wrapped when a
    calibrated band is supplied. Returns (forecaster, confidence_scale)."""
    fcst = forecast_registry.make(forecaster)
    if band is not None:
        return fconf.wrap(fcst, band), band.scale
    return fcst, None


def predictive_controller(cfg, *, target: float = 0.70,
                          horizon_min: int = 15,
                          cooldown_min: float = 5.0,
                          forecaster="holt_winters",
                          band: fconf.ConformalBand | None = None,
                          conservative: bool = False) -> Controller:
    """Uniform predictive baseline over any registered forecaster.
    `conservative=True` scales to the interval's upper bound instead of
    the point forecast (pay replicas for forecast uncertainty)."""
    fcst, _ = _resolve_forecaster(forecaster, band)

    def init():
        return PredState(fc=fcst.init())

    def on_minute(state: PredState, hist, minute_idx):
        return PredState(fc=fcst.update(state.fc, hist[-1]))

    def decide(state: PredState, obs: Obs):
        iv = fcst.forecast(state.fc, horizon_min)
        pred_per_min = jnp.maximum(iv.hi if conservative else iv.point, 0.0)
        need_pred = pred_per_min / 60.0 / (cfg.rps_per_replica * target)
        need_now = obs.rate_rps / (cfg.rps_per_replica * target)
        desired = jnp.ceil(jnp.maximum(need_pred, need_now))
        # scale to zero when neither live traffic nor forecast needs pods
        idle = ((desired < 1.0) & (obs.queue <= 0.0)
                & (obs.rate_rps <= 1e-6))
        desired = jnp.where(idle, 0.0, jnp.maximum(desired, 1.0))
        return state, desired, jnp.float32(cooldown_min * 60.0)

    def explain(state: PredState, obs: Obs):
        iv = fcst.forecast(state.fc, horizon_min)
        return ExplainOut(fc_point=iv.point, fc_lo=iv.lo, fc_hi=iv.hi,
                          confidence=_nan(), archetype=_nan(),
                          guard_floor=_nan())

    return Controller("predictive", init, on_minute, decide, explain)


# ------------------------------------------------------------------ AAPA ----
class AAPAState(NamedTuple):
    fc: fapi.FState         # named forecaster carry (PERIODIC strategy)
    arch: jax.Array         # int32 current archetype
    conf: jax.Array         # f32 effective confidence fed to Algorithm 1
    cpu_adj: jax.Array
    cool_adj_min: jax.Array
    minrep_adj: jax.Array


def aapa_controller(
        cfg,
        classify: Callable[[jax.Array], tuple[jax.Array, jax.Array]],
        *, stride_min: int = 10, horizon_min: int = 15,
        forecaster="holt_winters",
        band: fconf.ConformalBand | None = None,
        forecast_confidence: bool | None = None) -> Controller:
    """`classify(features [38]) -> (class id int32, confidence f32)`,
    typically GBDT + beta calibration (see ``repro.core.pipeline``).

    The predictive strategy runs any registered forecaster (by name or
    instance). When forecast confidence is on, Algorithm 1's confidence
    is the classifier's calibrated confidence *times* the forecast
    confidence — the forecaster's interval width mapped to [0, 1]
    (split-conformal when a calibrated `band` is supplied, residual-EWMA
    native band otherwise). Wide bands mean the forecast cannot be
    trusted, so the adjustment gets more conservative exactly as
    §III.C.3 prescribes. `forecast_confidence=None` (default) enables
    the signal only when a calibrated `band` is present, so an
    uncalibrated AAPA feeds the classifier signal alone."""
    tab = table_iii_arrays()
    fcst, conf_scale = _resolve_forecaster(forecaster, band)
    if forecast_confidence is None:
        forecast_confidence = band is not None

    def init():
        return AAPAState(fc=fcst.init(),
                         arch=jnp.int32(2),          # start conservative
                         conf=jnp.float32(0.5),
                         cpu_adj=jnp.float32(0.5),
                         cool_adj_min=jnp.float32(5.0),
                         minrep_adj=jnp.float32(1.0))

    def on_minute(state: AAPAState, hist, minute_idx):
        fst = fcst.update(state.fc, hist[-1])

        def reclassify(_):
            feats = F.extract_features(hist)
            arch, conf = classify(feats)
            if forecast_confidence:
                iv = fcst.forecast(fst, horizon_min)
                conf = conf * fapi.interval_confidence(iv, conf_scale)
            adj = uncertainty.adjust(conf,
                                     _select4(arch, *tab["target_cpu"]),
                                     _select4(arch, *tab["cooldown_min"]),
                                     _select4(arch, *tab["min_replicas"]))
            return AAPAState(fst, arch, conf, adj.target_cpu,
                             adj.cooldown_min, adj.min_replicas)

        def keep(_):
            return state._replace(fc=fst)

        do = (minute_idx % stride_min) == 0
        return jax.lax.cond(do, reclassify, keep, None)

    def decide(state: AAPAState, obs: Obs):
        cap = cfg.rps_per_replica * jnp.maximum(state.cpu_adj, 0.05)
        # reactive component (archetype-specific utilization target)
        ratio = obs.util_ema / jnp.maximum(state.cpu_adj, 0.05)
        reactive = jnp.ceil(obs.ready_total * ratio)
        reactive = jnp.where(jnp.abs(ratio - 1.0) <= 0.1,
                             obs.ready_total, reactive)

        # strategy components (paper Table III)
        warm = _select4(state.arch, *tab["warm_pool"])
        need_now = jnp.ceil(obs.rate_rps / cap)
        spike_d = need_now + warm + state.minrep_adj

        fc_pred = jnp.maximum(fcst.forecast(state.fc, horizon_min).point,
                              0.0) / 60.0
        periodic_d = jnp.ceil(fc_pred / cap)

        trend_pred = fc.linear_trend_forecast(
            obs.rate_history[-30:], horizon_min) / 60.0
        ramp_d = jnp.ceil(jnp.maximum(trend_pred, obs.rate_rps) / cap)

        mean_rps = jnp.mean(obs.rate_history[-15:]) / 60.0
        stat_d = jnp.ceil(mean_rps / cap)

        strat = _select4(state.arch, periodic_d, spike_d, stat_d, ramp_d)
        desired = jnp.maximum(jnp.maximum(reactive, strat),
                              jnp.maximum(state.minrep_adj, 1.0))
        return state, desired, state.cool_adj_min * 60.0

    def explain(state: AAPAState, obs: Obs):
        iv = fcst.forecast(state.fc, horizon_min)
        return ExplainOut(fc_point=iv.point, fc_lo=iv.lo, fc_hi=iv.hi,
                          confidence=state.conf,
                          archetype=state.arch.astype(jnp.float32),
                          guard_floor=_nan())

    return Controller("aapa", init, on_minute, decide, explain)


# ------------------------------------------------------------------- KPA ----
class KPAState(NamedTuple):
    stable_ema: jax.Array    # concurrency, ~stable_window average
    panic_ema: jax.Array     # concurrency, ~panic_window average
    panic_left_s: jax.Array  # seconds of panic mode remaining
    panic_max: jax.Array     # max desired seen during the panic


def kpa_controller(cfg, *, target_concurrency: float | None = None,
                   panic_threshold: float = 2.0,
                   stable_window_s: float = 60.0,
                   panic_window_s: float = 6.0,
                   cooldown_min: float = 1.0) -> Controller:
    """Knative-KPA-style concurrency autoscaler.

    Estimated in-flight concurrency (Little's law: rate x service time,
    plus the standing queue) feeds two EMAs. The stable window drives
    steady-state sizing; when the panic-window estimate needs more than
    `panic_threshold` x the current fleet, the scaler enters panic mode
    for one stable window, during which desired is pinned to the maximum
    seen (never scales down mid-burst).
    """
    if target_concurrency is None:
        # one replica's concurrency at full utilization
        target_concurrency = cfg.rps_per_replica * cfg.service_sec
    dt = float(cfg.control_interval_sec)

    def init():
        return KPAState(stable_ema=jnp.float32(0.0),
                        panic_ema=jnp.float32(0.0),
                        panic_left_s=jnp.float32(0.0),
                        panic_max=jnp.float32(0.0))

    def on_minute(state, hist, minute_idx):
        return state

    def decide(state: KPAState, obs: Obs):
        conc = obs.queue + obs.rate_rps * cfg.service_sec
        a_s = jnp.float32(min(dt / stable_window_s, 1.0))
        a_p = jnp.float32(min(dt / panic_window_s, 1.0))
        stable = state.stable_ema + a_s * (conc - state.stable_ema)
        panic = state.panic_ema + a_p * (conc - state.panic_ema)

        tgt = jnp.float32(target_concurrency)
        want_stable = jnp.ceil(stable / tgt)
        want_panic = jnp.ceil(panic / tgt)

        fleet = jnp.maximum(obs.ready_total, 1.0)
        enter = want_panic >= panic_threshold * fleet
        panic_left = jnp.where(enter, jnp.float32(stable_window_s),
                               jnp.maximum(state.panic_left_s - dt, 0.0))
        in_panic = panic_left > 0.0
        panic_max = jnp.where(
            in_panic, jnp.maximum(jnp.where(state.panic_left_s > 0.0,
                                            state.panic_max, 0.0),
                                  jnp.maximum(want_panic, fleet)),
            jnp.float32(0.0))
        desired = jnp.where(in_panic, panic_max, want_stable)

        # scale-to-zero on a truly idle stable window; wake on traffic
        idle = ((stable <= 1e-3) & (obs.queue <= 0.0)
                & (obs.rate_rps <= 1e-6))
        desired = jnp.where(idle, 0.0, jnp.maximum(desired, 1.0))
        return (KPAState(stable, panic, panic_left, panic_max), desired,
                jnp.float32(cooldown_min * 60.0))

    return Controller("kpa", init, on_minute, decide)


# ---------------------------------------------------------------- hybrid ----
def hybrid_controller(cfg, classify, *, guard_target: float = 0.85,
                      max_down_frac: float = 0.3,
                      **aapa_kw) -> Controller:
    """AAPA plus a reactive guardrail.

    Two failure modes of a pure archetype strategy are fenced off:

    * misclassification under-provisioning — desired never drops below
      what live utilization requires at `guard_target` (an HPA-style
      floor computed from the actual load, independent of the archetype);
    * scale-down cliffs — one decision may remove at most
      `max_down_frac` of the current fleet.

    State and classification cadence are inherited from
    ``aapa_controller``; only `decide` is wrapped.
    """
    base = aapa_controller(cfg, classify, **aapa_kw)

    def decide(state, obs: Obs):
        state, desired, cool = base.decide(state, obs)
        # reactive floor from live utilization
        floor = jnp.ceil(obs.ready_total * obs.util_ema / guard_target)
        floor = jnp.maximum(floor,
                            jnp.ceil(obs.rate_rps
                                     / (cfg.rps_per_replica
                                        * guard_target)))
        guarded = jnp.maximum(desired, floor)
        # bounded scale-down step
        step_floor = jnp.ceil(obs.ready_total * (1.0 - max_down_frac))
        guarded = jnp.where(guarded < obs.ready_total,
                            jnp.maximum(guarded, step_floor), guarded)
        return state, guarded, cool

    def explain(state, obs: Obs):
        floor = jnp.ceil(obs.ready_total * obs.util_ema / guard_target)
        floor = jnp.maximum(floor,
                            jnp.ceil(obs.rate_rps
                                     / (cfg.rps_per_replica
                                        * guard_target)))
        return base.explain(state, obs)._replace(guard_floor=floor)

    return Controller("hybrid", base.init, base.on_minute, decide, explain)
