"""Whisper-style encoder-decoder backbone (audio frontend is a STUB:
``input_specs()`` provides precomputed 1500-frame embeddings, per the
assignment). Encoder: non-causal self-attention; decoder: causal self +
cross attention. Both stacks are lax.scan-stacked.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import layers as Lyr
from repro.models.common import ModelConfig


def _init_enc_layer(rng, cfg):
    ks = jax.random.split(rng, 2)
    return {"ln1": Lyr.init_rms(cfg.d_model),
            "ln2": Lyr.init_rms(cfg.d_model),
            "attn": Lyr.init_attention(ks[0], cfg),
            "mlp": Lyr.init_mlp(ks[1], cfg)}


def _init_dec_layer(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {"ln1": Lyr.init_rms(cfg.d_model),
            "ln2": Lyr.init_rms(cfg.d_model),
            "ln3": Lyr.init_rms(cfg.d_model),
            "self_attn": Lyr.init_attention(ks[0], cfg),
            "cross_attn": Lyr.init_attention(ks[1], cfg),
            "mlp": Lyr.init_mlp(ks[2], cfg)}


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 5)
    enc_ks = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_ks = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": jax.random.normal(ks[2], (cfg.vocab, cfg.d_model),
                                   cfg.jdtype) * 0.02,
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_ks),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_ks),
        "enc_norm": Lyr.init_rms(cfg.d_model),
        "final_norm": Lyr.init_rms(cfg.d_model),
        "lm_head": jax.random.normal(ks[3], (cfg.d_model, cfg.vocab),
                                     cfg.jdtype) * cfg.d_model**-0.5,
    }


def encode(params, enc_embeds, cfg: ModelConfig, *, remat=True):
    """enc_embeds [B, T_enc, D] (stub frontend output) -> [B, T_enc, D]."""
    def body(h, lp):
        a = Lyr.rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
        a, _ = Lyr.attention(lp["attn"], a, cfg, causal=False)
        h = h + a
        m = Lyr.rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
        h = h + Lyr.mlp(lp["mlp"], m)
        return shd.constrain(h, ("dp", "mp", None)), None

    if Lyr.unroll():  # cost-probe mode
        h = enc_embeds.astype(cfg.jdtype)
        for i in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
            h, _ = (jax.checkpoint(body) if remat else body)(h, lp)
        return Lyr.rms_norm(h, params["enc_norm"]["scale"], cfg.norm_eps)
    body_fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body_fn, enc_embeds.astype(cfg.jdtype),
                        params["enc_layers"])
    return Lyr.rms_norm(h, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_block(lp, h, enc_out, cfg, *, cache=None, pos=None):
    a = Lyr.rms_norm(h, lp["ln1"]["scale"], cfg.norm_eps)
    self_cache = None if cache is None else cache["self"]
    a, new_self = Lyr.attention(lp["self_attn"], a, cfg, cache=self_cache,
                                pos=pos)
    h = h + a
    c = Lyr.rms_norm(h, lp["ln2"]["scale"], cfg.norm_eps)
    c, _ = Lyr.attention(lp["cross_attn"], c, cfg, kv_x=enc_out,
                         causal=False, use_rope=False)
    h = h + c
    m = Lyr.rms_norm(h, lp["ln3"]["scale"], cfg.norm_eps)
    h = h + Lyr.mlp(lp["mlp"], m)
    new_cache = None if cache is None else {"self": new_self}
    return h, new_cache


def forward(params, batch, cfg: ModelConfig, *, remat=True,
            return_hidden: bool = False):
    """Training forward: batch {"tokens": [B,S], "enc_embeds": [B,T,D]}.
    Returns (logits [B,S,V], aux=0)."""
    enc_out = encode(params, batch["enc_embeds"], cfg, remat=remat)
    h = params["embed"][batch["tokens"]]

    def body(carry, lp):
        h = carry
        h, _ = _dec_block(lp, h, enc_out, cfg)
        return shd.constrain(h, ("dp", "mp", None)), None

    if Lyr.unroll():  # cost-probe mode
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec_layers"])
            h, _ = (jax.checkpoint(body) if remat else body)(h, lp)
    else:
        body_fn = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(body_fn, h, params["dec_layers"])
    h = Lyr.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if return_hidden:
        return h, jnp.float32(0.0)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits, jnp.float32(0.0)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    def one(_):
        return {"self": Lyr.init_kv_cache(cfg, batch, max_len)}
    return {"dec": jax.vmap(one)(jnp.arange(cfg.n_layers)),
            "enc_out": jnp.zeros((batch, cfg.enc_len, cfg.d_model),
                                 cfg.jdtype)}


def _run_dec_stack(params, dec_cache, h, enc_out, cfg, pos):
    """Decoder stack with the cache as scan carry, updated in place (no
    stacked second copy — see transformer._scan_layers_inplace)."""

    def one(h, cache, li):
        lp = jax.tree.map(lambda a: a[li], params["dec_layers"])
        lc = jax.tree.map(lambda a: a[li], cache)
        h, nc = _dec_block(lp, h, enc_out, cfg, cache=lc, pos=pos)
        cache = jax.tree.map(
            lambda full, u: jax.lax.dynamic_update_index_in_dim(
                full, u.astype(full.dtype), li, 0), cache, nc)
        return h, cache

    if Lyr.unroll():  # cost-probe mode
        cache = dec_cache
        for i in range(cfg.n_layers):
            h, cache = one(h, cache, i)
        return h, cache

    def body(carry, i):
        h, cache = carry
        h, cache = one(h, cache, i)
        return (h, cache), None

    (h, cache), _ = jax.lax.scan(body, (h, dec_cache),
                                 jnp.arange(cfg.n_layers))
    return h, cache


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Encode + run the decoder prompt. Returns (last logits, cache)."""
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len)
    enc_out = encode(params, batch["enc_embeds"], cfg, remat=False)
    h = params["embed"][batch["tokens"]]
    h, new_dec = _run_dec_stack(params, cache["dec"], h, enc_out, cfg, 0)
    h = Lyr.rms_norm(h[:, -1:], params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits, {"dec": new_dec, "enc_out": enc_out}


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    h = params["embed"][tokens]
    enc_out = cache["enc_out"]
    h, new_dec = _run_dec_stack(params, cache["dec"], h, enc_out, cfg, pos)
    h = Lyr.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits, {"dec": new_dec, "enc_out": enc_out}
