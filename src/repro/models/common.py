"""Model configuration shared across all architecture families."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe_mla | moe_gqa | ssm | hybrid
                               # | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0        # 0 -> = n_heads (MHA)
    head_dim: int = 0          # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0
    d_ff_dense: int = 0        # d_ff of the leading dense layers (MoE archs)
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (Mamba2 SSD) ---
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    n_groups: int = 1
    # --- hybrid (Zamba2) ---
    attn_every: int = 0        # shared attention block period (0 = none)
    # --- enc-dec (Whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 0           # encoder frames (precomputed embeddings stub)
    # --- VLM ---
    n_img_tokens: int = 0      # prepended patch embeddings (stub frontend)
    # --- misc ---
    qk_norm: bool = False      # Qwen3-style q/k RMSNorm
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # KV/latent cache storage dtype; "float8_e4m3fn" halves the
    # memory-bound decode roofline term (§Roofline-summary)
    cache_dtype: str = "bfloat16"
    # long-context capability flag (sub-quadratic decode path exists)
    subquadratic: bool = False

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def cache_jdtype(self):
        return jnp.dtype(self.cache_dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS and documentation."""
        D, V = self.d_model, self.vocab
        total = 2 * V * D  # embed + lm head
        if self.family in ("dense", "vlm"):
            total += self.n_layers * self._dense_layer_params()
        elif self.family in ("moe_mla", "moe_gqa"):
            dense_l = self.first_k_dense
            moe_l = self.n_layers - dense_l
            total += dense_l * self._dense_layer_params(self.d_ff_dense)
            attn = self._attn_params()
            ff_e = 3 * D * self.d_ff_expert
            shared = self.n_shared_experts * ff_e
            total += moe_l * (attn + self.n_experts * ff_e + shared
                              + D * self.n_experts)
        elif self.family == "ssm":
            total += self.n_layers * self._ssm_layer_params()
        elif self.family == "hybrid":
            total += self.n_layers * self._ssm_layer_params()
            total += self._dense_layer_params()  # one shared attn block
        elif self.family == "encdec":
            total += self.n_enc_layers * self._dense_layer_params()
            # decoder layers have an extra cross-attention
            total += self.n_layers * (self._dense_layer_params()
                                      + self._attn_params())
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared)."""
        if self.family not in ("moe_mla", "moe_gqa"):
            return self.param_count()
        D = self.d_model
        dense_l = self.first_k_dense
        moe_l = self.n_layers - dense_l
        attn = self._attn_params()
        ff_e = 3 * D * self.d_ff_expert
        total = 2 * self.vocab * D
        total += dense_l * self._dense_layer_params(self.d_ff_dense)
        total += moe_l * (attn + (self.top_k + self.n_shared_experts) * ff_e
                          + D * self.n_experts)
        return total

    def _attn_params(self) -> int:
        D = self.d_model
        if self.kv_lora_rank:  # MLA
            qdim = self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            kv_in = self.kv_lora_rank + self.qk_rope_dim
            expand = self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim)
            out = self.n_heads * self.v_head_dim * D
            return D * qdim + D * kv_in + expand + out
        H, KV, hd = self.n_heads, self.kv_heads, self.hdim
        return D * hd * (H + 2 * KV) + H * hd * D

    def _dense_layer_params(self, d_ff: int | None = None) -> int:
        return self._attn_params() + 3 * self.d_model * (d_ff or self.d_ff)

    def _ssm_layer_params(self) -> int:
        D, Din, N = self.d_model, self.d_inner, self.d_state
        G = self.n_groups
        in_proj = D * (2 * Din + 2 * G * N + self.ssm_heads)
        conv = self.d_conv * (Din + 2 * G * N)
        out = Din * D
        return in_proj + conv + out + 2 * self.ssm_heads
