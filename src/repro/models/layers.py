"""Core transformer layers: RMSNorm, RoPE, chunked-flash GQA attention,
MLA (DeepSeek-V2 multi-head latent attention), SwiGLU MLP.

Conventions: params are nested dicts of arrays; functions are pure.
Activations default to bf16, accumulation/softmax in f32.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig

NEG_INF = -1e30

# Cost-probe mode (see launch/roofline.py): XLA cost_analysis counts a
# scan body once regardless of trip count, so roofline probes unroll every
# inner loop (flash tiles, SSD chunks, CE chunks, layer stacks) into
# straight-line HLO. Never enabled in production paths.
_UNROLL = False


def set_unroll(v: bool) -> None:
    global _UNROLL
    _UNROLL = v


def unroll() -> bool:
    return _UNROLL


def rms_norm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def init_rms(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


# ------------------------------------------------------------------ RoPE ----
def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta=10000.0):
    """x [..., S, H, hd] (hd even), positions [..., S] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]                     # [..., S, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------- chunked flash attention ----
def _flash_q_chunk(q, k, v, q_pos0, kv_chunk, scale, causal=True,
                   kv_valid=None, unroll_kv=False):
    """Online-softmax attention of one query chunk against all of k/v.

    q [B, qc, H, hd]; k/v [B, S, KV, hd]; causal with absolute offset
    q_pos0. Scans kv chunks carrying (m, l, acc) in f32.
    """
    B, qc, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, qc, KV, G, hd)
    n_kv = S // kv_chunk

    def body(carry, i):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, i * kv_chunk, kv_chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, i * kv_chunk, kv_chunk, 1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            q_ids = q_pos0 + jnp.arange(qc)
            kv_ids = i * kv_chunk + jnp.arange(kv_chunk)
            mask = q_ids[:, None] >= kv_ids[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        if kv_valid is not None:
            vmask = jax.lax.dynamic_slice_in_dim(kv_valid, i * kv_chunk,
                                                 kv_chunk, 0)
            s = jnp.where(vmask[None, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vs.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
    a0 = jnp.zeros((B, KV, G, qc, hd), jnp.float32)
    if unroll_kv:
        carry = (m0, l0, a0)
        for i in range(n_kv):
            carry, _ = body(carry, jnp.int32(i))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(n_kv))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, hd)
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, q_chunk=512, kv_chunk=1024, causal=True):
    """Chunked attention. q [B,Sq,H,hd], k/v [B,Skv,KV,hd] -> [B,Sq,H,hd].

    Pure-JAX flash: O(chunk^2) memory, online softmax, GQA by grouping.
    Non-causal (causal=False) supports cross/encoder attention with
    Sq != Skv.
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, Skv)
    q_pad = 0
    if S % q_chunk:  # pad queries to a chunk multiple, slice the result
        q_pad = q_chunk - S % q_chunk
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        S = S + q_pad
    if Skv % kv_chunk:  # pad kv to a chunk multiple with masked tail
        pad = kv_chunk - Skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if not causal:  # causal mask already excludes the tail
            kv_valid = jnp.arange(Skv + pad) < Skv
        else:
            kv_valid = None
    else:
        kv_valid = None
    scale = 1.0 / (hd ** 0.5)
    if _UNROLL:
        q_chunk = min(2048, S)
        kv_chunk = min(2048, k.shape[1])
    nq = S // q_chunk
    qs = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    # checkpoint each query chunk: backward recomputes the chunk's scores
    # instead of storing per-kv-iteration probability tiles (the flash-
    # attention memory property, at ~+1/3 attention flops in backward)
    @jax.checkpoint
    def one(args):
        i, qb = args
        return _flash_q_chunk(qb, k, v, i * q_chunk, kv_chunk, scale,
                              causal=causal, kv_valid=kv_valid,
                              unroll_kv=_UNROLL)

    if _UNROLL:
        outs = jnp.stack([one((jnp.int32(i), qs[i])) for i in range(nq)])
    else:
        outs = jax.lax.map(one, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return out[:, :S - q_pad] if q_pad else out


def decode_attention(q, k_cache, v_cache, pos, scale=None):
    """Single-token attention over a cache.

    q [B,1,H,hd]; caches [B,S,KV,hd] (any storage dtype — fp8 caches are
    upcast at use); pos [] int32 = index of the new token (attends to
    cache positions <= pos). Returns [B,1,H,hd].
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale or 1.0 / (hd ** 0.5)
    if k_cache.dtype.itemsize < 2:  # fp8 storage -> bf16 compute
        k_cache = k_cache.astype(q.dtype)
        v_cache = v_cache.astype(q.dtype)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ------------------------------------------------------------ GQA block ----
def init_attention(rng, cfg: ModelConfig):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hdim
    k = jax.random.split(rng, 4)
    std = D ** -0.5
    p = {
        "wq": jax.random.normal(k[0], (D, H, hd), cfg.jdtype) * std,
        "wk": jax.random.normal(k[1], (D, KV, hd), cfg.jdtype) * std,
        "wv": jax.random.normal(k[2], (D, KV, hd), cfg.jdtype) * std,
        "wo": jax.random.normal(k[3], (H, hd, D), cfg.jdtype) * std,
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(hd)
        p["k_norm"] = init_rms(hd)
    return p


def attention(p, x, cfg: ModelConfig, *, positions=None, cache=None,
              pos=None, kv_x=None, causal=True, use_rope=True):
    """GQA attention. x [B,S,D].

    Training/prefill: cache=None, full causal flash. If `cache` is given
    (dict with k/v [B,Smax,KV,hd]) and S==1, runs a decode step writing at
    `pos` and returns (out, new_cache); prefill with cache returns the
    populated cache. Cross attention: pass kv_x (keys/values source) and
    causal=False; with a cache, cross k/v are computed once at prefill and
    reused at decode (pass kv_x=None then).
    """
    B, S, D = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)

    if use_rope:
        if positions is None:
            if cache is not None and S == 1:
                positions = jnp.full((B, 1), pos, jnp.int32)
            else:
                positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q = apply_rope(q, positions, cfg.rope_theta)
        kv_pos = jnp.broadcast_to(jnp.arange(k.shape[1]), k.shape[:2]) \
            if kv_x is not None else positions
        k = apply_rope(k, kv_pos, cfg.rope_theta)

    if cache is None:
        out = flash_attention(q, k, v, causal=causal)
        new_cache = None
    elif S == 1:  # decode
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"],
                                                 k.astype(cache["k"].dtype),
                                                 pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"],
                                                 v.astype(cache["v"].dtype),
                                                 pos, 1)
        out = decode_attention(q, kc, vc, pos)
        new_cache = {"k": kc, "v": vc}
    else:  # prefill into cache
        out = flash_attention(q, k, v)
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, 1)
        new_cache = {"k": kc, "v": vc}

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {"k": jnp.zeros((batch, max_len, cfg.kv_heads, cfg.hdim),
                           cfg.cache_jdtype),
            "v": jnp.zeros((batch, max_len, cfg.kv_heads, cfg.hdim),
                           cfg.cache_jdtype)}


# ------------------------------------------------------------------- MLA ----
def init_mla(rng, cfg: ModelConfig):
    """DeepSeek-V2 multi-head latent attention (no q compression, as in
    V2-Lite): q proj full rank; kv compressed to kv_lora_rank + rope dims."""
    D, H = cfg.d_model, cfg.n_heads
    L, rd, nd, vd = (cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim,
                     cfg.v_head_dim)
    k = jax.random.split(rng, 5)
    std = D ** -0.5
    return {
        "wq": jax.random.normal(k[0], (D, H, nd + rd), cfg.jdtype) * std,
        "w_dkv": jax.random.normal(k[1], (D, L + rd), cfg.jdtype) * std,
        "kv_norm": init_rms(L),
        "w_uk": jax.random.normal(k[2], (L, H, nd), cfg.jdtype) * (L ** -0.5),
        "w_uv": jax.random.normal(k[3], (L, H, vd), cfg.jdtype) * (L ** -0.5),
        "wo": jax.random.normal(k[4], (H, vd, D), cfg.jdtype) * std,
    }


def mla_attention(p, x, cfg: ModelConfig, *, cache=None, pos=None):
    """MLA forward. Cache holds the compressed c_kv and rope key only —
    the paper-faithful memory saving. Decode uses the absorption trick
    (scores computed in latent space; no per-step re-expansion)."""
    B, S, D = x.shape
    H = cfg.n_heads
    L, rd, nd, vd = (cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim,
                     cfg.v_head_dim)
    scale = 1.0 / ((nd + rd) ** 0.5)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])          # [B,S,H,nd+rd]
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    ckv_full = jnp.einsum("bsd,dk->bsk", x, p["w_dkv"])  # [B,S,L+rd]
    c_kv = rms_norm(ckv_full[..., :L], p["kv_norm"]["scale"], cfg.norm_eps)
    k_pe = ckv_full[..., L:][:, :, None, :]              # [B,S,1,rd]

    if cache is not None and S == 1:  # ---- decode (absorbed) ----
        positions = jnp.full((B, 1), pos, jnp.int32)
        q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
        k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, 1)
        kpe_c = jax.lax.dynamic_update_slice_in_dim(
            cache["k_pe"], k_pe[:, :, 0].astype(cache["k_pe"].dtype), pos, 1)
        new_cache = {"c_kv": ckv_c, "k_pe": kpe_c}
        if ckv_c.dtype.itemsize < 2:  # fp8 storage -> bf16 compute
            ckv_c = ckv_c.astype(x.dtype)
            kpe_c = kpe_c.astype(x.dtype)
        # absorb W_uk into the query: q_lat [B,H,L]
        q_lat = jnp.einsum("bshk,lhk->bshl", q_nope, p["w_uk"])[:, 0]
        s = (jnp.einsum("bhl,bsl->bhs", q_lat, ckv_c,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhk,bsk->bhs", q_pe[:, 0], kpe_c,
                          preferred_element_type=jnp.float32)) * scale
        Smax = ckv_c.shape[1]
        s = jnp.where((jnp.arange(Smax) <= pos)[None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsl->bhl", pr.astype(ckv_c.dtype), ckv_c,
                           preferred_element_type=jnp.float32)
        out = jnp.einsum("bhl,lhv->bhv", o_lat.astype(x.dtype), p["w_uv"])
        out = out[:, None]                                # [B,1,H,vd]
    else:  # ---- train / prefill (expanded) ----
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
        k_pe = apply_rope(k_pe, positions, cfg.rope_theta)
        k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["w_uk"])
        v = jnp.einsum("bsl,lhv->bshv", c_kv, p["w_uv"])
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe, (B, S, H, rd))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
        # pad v to qk head dim for the shared flash kernel, slice after
        pad = (nd + rd) - vd
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
        out = flash_attention(q_full, k_full, v_pad)[..., :vd]
        if cache is not None:
            ckv_c = jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), 0, 1)
            kpe_c = jax.lax.dynamic_update_slice_in_dim(
                cache["k_pe"], k_pe[:, :, 0].astype(cache["k_pe"].dtype),
                0, 1)
            new_cache = {"c_kv": ckv_c, "k_pe": kpe_c}
        else:
            new_cache = None

    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    return {"c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank),
                              cfg.cache_jdtype),
            "k_pe": jnp.zeros((batch, max_len, cfg.qk_rope_dim),
                              cfg.cache_jdtype)}


# ---------------------------------------------------------------- SwiGLU ----
def init_mlp(rng, cfg: ModelConfig, d_ff: int | None = None):
    D = cfg.d_model
    Ff = d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    return {
        "w_gate": jax.random.normal(k[0], (D, Ff), cfg.jdtype) * D**-0.5,
        "w_up": jax.random.normal(k[1], (D, Ff), cfg.jdtype) * D**-0.5,
        "w_down": jax.random.normal(k[2], (Ff, D), cfg.jdtype) * Ff**-0.5,
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]
