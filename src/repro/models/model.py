"""Family dispatch: one API over decoder-only and encoder-decoder models."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.common import ModelConfig


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else transformer


def init(rng, cfg: ModelConfig):
    return _mod(cfg).init(rng, cfg)


def forward(params, batch, cfg: ModelConfig, *, remat: bool = True,
            return_hidden: bool = False):
    return _mod(cfg).forward(params, batch, cfg, remat=remat,
                             return_hidden=return_hidden)


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    return _mod(cfg).prefill(params, batch, cfg, max_len)


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    return _mod(cfg).decode_step(params, cache, tokens, pos, cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return _mod(cfg).init_cache(cfg, batch, max_len)


def _ce_chunk(args):
    """CE over one sequence chunk (rematted: logits never persist)."""
    hc, labels_c, lm_head = args
    logits = jnp.einsum("bsd,dv->bsv", hc, lm_head).astype(jnp.float32)
    valid = labels_c >= 0
    safe = jnp.where(valid, labels_c, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return (-jnp.sum(jnp.where(valid, ll, 0.0)),
            jnp.sum(valid).astype(jnp.float32))


def loss_fn(params, batch, cfg: ModelConfig, *, remat: bool = True,
            aux_weight: float = 0.01, ce_chunk: int = 512):
    """Next-token cross-entropy (+ MoE aux), computed in sequence chunks so
    the full-vocab [B,S,V] logits tensor never materializes. batch needs
    "tokens" and "labels" (-100 = ignore)."""
    h, aux = forward(params, batch, cfg, remat=remat, return_hidden=True)
    labels = batch["labels"]
    B, S, D = h.shape
    c = ce_chunk if S % ce_chunk == 0 else S
    nc = S // c
    hc = h.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
    fn = jax.checkpoint(_ce_chunk) if (remat and nc > 1) else _ce_chunk
    from repro.models import layers as Lyr
    if Lyr.unroll():
        outs = [fn((hc[i], lc[i], params["lm_head"])) for i in range(nc)]
        nll = jnp.stack([o[0] for o in outs])
        cnt = jnp.stack([o[1] for o in outs])
    else:
        nll, cnt = jax.lax.map(
            lambda a: fn((a[0], a[1], params["lm_head"])), (hc, lc))
    ce = jnp.sum(nll) / jnp.maximum(jnp.sum(cnt), 1.0)
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}
