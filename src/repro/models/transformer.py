"""Unified decoder-only model covering the dense / moe_mla / moe_gqa /
ssm / hybrid / vlm families. Layers are lax.scan-stacked (single-layer HLO
=> tractable 512-device compiles) with optional remat.

API:
    init(rng, cfg)                    -> params
    forward(params, batch, cfg)       -> (logits, aux)   [training]
    prefill(params, tokens, cfg, L)   -> (logits_last, cache)
    decode_step(params, cache, tok, pos, cfg) -> (logits, cache)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import layers as Lyr
from repro.models import moe as Moe
from repro.models import ssm as Ssm
from repro.models.common import ModelConfig


# ----------------------------------------------------------------- blocks ----
def init_block(rng, cfg: ModelConfig, *, dense_ff: bool = False):
    """One residual block's params for the given family."""
    ks = jax.random.split(rng, 4)
    if cfg.family in ("ssm", "hybrid"):
        return {"norm": Lyr.init_rms(cfg.d_model),
                "mixer": Ssm.init_mamba2(ks[0], cfg)}
    p = {"ln1": Lyr.init_rms(cfg.d_model), "ln2": Lyr.init_rms(cfg.d_model)}
    if cfg.family == "moe_mla":
        p["attn"] = Lyr.init_mla(ks[0], cfg)
    else:
        p["attn"] = Lyr.init_attention(ks[0], cfg)
    if cfg.family in ("moe_mla", "moe_gqa") and not dense_ff:
        p["moe"] = Moe.init_moe(ks[1], cfg)
    else:
        ff = cfg.d_ff_dense if (dense_ff and cfg.d_ff_dense) else cfg.d_ff
        p["mlp"] = Lyr.init_mlp(ks[1], cfg, d_ff=ff)
    return p


def block_forward(p, x, cfg: ModelConfig, *, cache=None, pos=None,
                  dense_ff: bool = False):
    """Residual block. Returns (x, aux, new_cache)."""
    aux = jnp.float32(0.0)
    if cfg.family in ("ssm", "hybrid"):
        h, new_cache = Ssm.mamba2_block(
            p["mixer"], Lyr.rms_norm(x, p["norm"]["scale"], cfg.norm_eps),
            cfg, cache=cache, pos=pos)
        return x + h, aux, new_cache

    h = Lyr.rms_norm(x, p["ln1"]["scale"], cfg.norm_eps)
    if cfg.family == "moe_mla":
        h, attn_cache = Lyr.mla_attention(p["attn"], h, cfg, cache=cache,
                                          pos=pos)
    else:
        h, attn_cache = Lyr.attention(p["attn"], h, cfg, cache=cache,
                                      pos=pos)
    x = x + h
    h = Lyr.rms_norm(x, p["ln2"]["scale"], cfg.norm_eps)
    if "moe" in p:
        h, aux = Moe.moe_block(p["moe"], h, cfg)
    else:
        h = Lyr.mlp(p["mlp"], h)
    return x + h, aux, attn_cache


# ------------------------------------------------------------------ model ----
def _n_scan_layers(cfg: ModelConfig) -> int:
    return cfg.n_layers - cfg.first_k_dense


def init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 6)
    params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                   cfg.jdtype) * 0.02,
        "final_norm": Lyr.init_rms(cfg.d_model),
        "lm_head": jax.random.normal(ks[1], (cfg.d_model, cfg.vocab),
                                     cfg.jdtype) * cfg.d_model**-0.5,
    }
    # leading dense layers of MoE archs live outside the scan
    if cfg.first_k_dense:
        dks = jax.random.split(ks[2], cfg.first_k_dense)
        params["dense_layers"] = [init_block(k, cfg, dense_ff=True)
                                  for k in dks]
    n_scan = _n_scan_layers(cfg)
    lks = jax.random.split(ks[3], n_scan)
    params["layers"] = jax.vmap(lambda k: init_block(k, cfg))(lks)
    if cfg.family == "hybrid" and cfg.attn_every:
        params["shared_attn"] = {
            "ln1": Lyr.init_rms(cfg.d_model),
            "ln2": Lyr.init_rms(cfg.d_model),
            "attn": Lyr.init_attention(ks[4], cfg),
            "mlp": Lyr.init_mlp(ks[5], cfg),
        }
    return params


def _shared_attn_block(sp, x, cfg, *, cache=None, pos=None):
    h = Lyr.rms_norm(x, sp["ln1"]["scale"], cfg.norm_eps)
    h, new_cache = Lyr.attention(sp["attn"], h, cfg, cache=cache, pos=pos)
    x = x + h
    h = Lyr.rms_norm(x, sp["ln2"]["scale"], cfg.norm_eps)
    return x + Lyr.mlp(sp["mlp"], h), new_cache


def _embed_inputs(params, batch, cfg: ModelConfig):
    """tokens (+ optional stub-frontend embeddings) -> h [B, S_total, D]."""
    h = params["embed"][batch["tokens"]]
    if cfg.n_img_tokens and "img_embeds" in batch:
        h = jnp.concatenate(
            [batch["img_embeds"].astype(h.dtype), h], axis=1)
    return h


def forward(params, batch, cfg: ModelConfig, *, remat: bool = True,
            return_hidden: bool = False):
    """Training forward. batch {"tokens": [B,S], ...} -> (logits, aux),
    or (hidden, aux) with return_hidden=True (chunked-CE path skips the
    full-vocab logits materialization)."""
    h = _embed_inputs(params, batch, cfg)
    h = shd.constrain(h, ("dp", None, None))
    aux_total = jnp.float32(0.0)

    for dp in params.get("dense_layers", []):
        h, aux, _ = block_forward(dp, h, cfg, dense_ff=True)
        aux_total += aux

    shared = params.get("shared_attn")

    def scan_body(carry, inp):
        h, aux_acc, idx = carry
        lp = inp
        h, aux, _ = block_forward(lp, h, cfg)
        if shared is not None and cfg.attn_every:
            def with_attn(h):
                out, _ = _shared_attn_block(shared, h, cfg)
                return out
            h = jax.lax.cond((idx + 1) % cfg.attn_every == 0,
                             with_attn, lambda h: h, h)
        # sequence-sharded carry: the remat stash (one [B,S,D] per layer)
        # shards over BOTH dp and the model axis; XLA re-gathers per layer
        # where attention needs the full sequence (sequence parallelism)
        h = shd.constrain(h, ("dp", "mp", None))
        return (h, aux_acc + aux, idx + 1), None

    if Lyr.unroll():  # cost-probe mode: straight-line layers
        n_scan = _n_scan_layers(cfg)
        for i in range(n_scan):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            step = jax.checkpoint(block_forward, static_argnums=(2,)) \
                if remat else block_forward
            h, aux, _ = step(lp, h, cfg)
            aux_total += aux
            if shared is not None and cfg.attn_every \
                    and (i + 1) % cfg.attn_every == 0:
                h, _ = _shared_attn_block(shared, h, cfg)
    else:
        body = jax.checkpoint(scan_body) if remat else scan_body
        (h, aux_total, _), _ = jax.lax.scan(
            body, (h, aux_total, jnp.int32(0)), params["layers"])

    h = Lyr.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    if cfg.n_img_tokens and "img_embeds" in batch:
        h = h[:, batch["img_embeds"].shape[1]:]   # loss on text positions
    if return_hidden:
        return h, aux_total
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return shd.constrain(logits, ("dp", None, "mp")), aux_total


# ------------------------------------------------------------------ cache ----
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked per-layer cache pytree (+ shared-attn caches for hybrid)."""
    n_scan = _n_scan_layers(cfg)

    def one_layer(_):
        if cfg.family in ("ssm", "hybrid"):
            return Ssm.init_ssm_cache(cfg, batch)
        if cfg.family == "moe_mla":
            return Lyr.init_mla_cache(cfg, batch, max_len)
        return Lyr.init_kv_cache(cfg, batch, max_len)

    stacked = jax.vmap(one_layer)(jnp.arange(n_scan))
    cache = {"layers": stacked}
    if cfg.first_k_dense:
        cache["dense_layers"] = [one_layer(0)
                                 for _ in range(cfg.first_k_dense)]
    if cfg.family == "hybrid" and cfg.attn_every:
        n_apps = n_scan // cfg.attn_every
        cache["shared"] = jax.vmap(
            lambda _: Lyr.init_kv_cache(cfg, batch, max_len))(
                jnp.arange(n_apps))
    return cache


def _scan_layers_inplace(params, cache_stacked, h, cfg: ModelConfig, *,
                         start: int, count: int, pos, update_at=None):
    """Run `count` stacked layers with the cache as scan carry, updated
    in place (lax.dynamic_update_index) — no second stacked cache copy is
    ever materialized, so decode/prefill memory is ~the cache itself.

    update_at: position written in the sequence dim for KV caches (decode:
    pos; prefill: 0). Returns (h, cache_stacked)."""

    def one(h, cache, li):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        lc = jax.tree.map(lambda a: a[li], cache)
        h, _, nc = block_forward(lp, h, cfg, cache=lc, pos=pos)
        cache = jax.tree.map(
            lambda full, u: jax.lax.dynamic_update_index_in_dim(
                full, u.astype(full.dtype), li, 0), cache, nc)
        return h, cache

    if Lyr.unroll():  # cost-probe mode: straight-line layers
        cache = cache_stacked
        for i in range(count):
            h, cache = one(h, cache, start + i)
        return h, cache

    def body(carry, i):
        h, cache = carry
        h, cache = one(h, cache, start + i)
        return (h, cache), None

    (h, cache), _ = jax.lax.scan(
        body, (h, cache_stacked), jnp.arange(count))
    return h, cache


def _run_stack_with_cache(params, cache, h, cfg: ModelConfig, pos):
    """Layer stack + (for hybrid) block-structured shared attention with
    per-application caches. Returns (h, new_cache)."""
    shared = params.get("shared_attn")
    n_scan = _n_scan_layers(cfg)
    layer_cache = cache["layers"]

    if shared is not None and cfg.attn_every:
        ae = cfg.attn_every
        n_apps = n_scan // ae
        shared_cache = cache["shared"]
        for app in range(n_apps):
            h, layer_cache = _scan_layers_inplace(
                params, layer_cache, h, cfg, start=app * ae, count=ae,
                pos=pos)
            sc = jax.tree.map(lambda c: c[app], shared_cache)
            h, new_sc = _shared_attn_block(shared, h, cfg, cache=sc,
                                           pos=pos)
            shared_cache = jax.tree.map(
                lambda full, u: full.at[app].set(u.astype(full.dtype)),
                shared_cache, new_sc)
        tail = n_scan - n_apps * ae
        if tail:
            h, layer_cache = _scan_layers_inplace(
                params, layer_cache, h, cfg, start=n_apps * ae,
                count=tail, pos=pos)
        new_cache = dict(cache)
        new_cache["layers"] = layer_cache
        new_cache["shared"] = shared_cache
        return h, new_cache

    h, layer_cache = _scan_layers_inplace(params, layer_cache, h, cfg,
                                          start=0, count=n_scan, pos=pos)
    new_cache = dict(cache)
    new_cache["layers"] = layer_cache
    return h, new_cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens [B,1] int32, pos [] int32.
    Returns (logits [B,1,V], new_cache)."""
    h = params["embed"][tokens]

    new_dense = []
    for dp, dc in zip(params.get("dense_layers", []),
                      cache.get("dense_layers", [])):
        h, _, nc = block_forward(dp, h, cfg, cache=dc, pos=pos,
                                 dense_ff=True)
        new_dense.append(nc)

    h, new_cache = _run_stack_with_cache(params, cache, h, cfg, pos)
    if new_dense:
        new_cache["dense_layers"] = new_dense

    h = Lyr.rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits, new_cache


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Populate a cache from a prompt. Returns (last-token logits, cache)."""
    cache = init_cache(cfg, batch["tokens"].shape[0], max_len)
    h = _embed_inputs(params, batch, cfg)
    h = shd.constrain(h, ("dp", None, None))

    new_dense = []
    for dp, dc in zip(params.get("dense_layers", []),
                      cache.get("dense_layers", [])):
        h, _, nc = block_forward(dp, h, cfg, cache=dc, pos=0,
                                 dense_ff=True)
        new_dense.append(nc)

    h, new_cache = _run_stack_with_cache(params, cache, h, cfg, pos=0)
    if new_dense:
        new_cache["dense_layers"] = new_dense

    h = Lyr.rms_norm(h[:, -1:], params["final_norm"]["scale"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    return logits, new_cache
