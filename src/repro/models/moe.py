"""Mixture-of-Experts FFN with capacity-based top-k routing.

Design (DESIGN.md §4): tokens are dispatched into a fixed-shape
``[E, C, D]`` buffer via cumsum position assignment + scatter, experts run
as batched matmuls, results gather back with gate-weighted combine. This
keeps compiled FLOPs proportional to *active* parameters (capacity-bounded)
and shards naturally under pjit: E over the "model" axis (expert
parallelism), token axis over ("pod","data").

Capacity C = ceil(tokens * top_k / E * capacity_factor); overflow tokens
drop to the shared/residual path (standard GShard semantics).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models.common import ModelConfig
from repro.models.layers import init_mlp, mlp


def init_moe(rng, cfg: ModelConfig):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    k = jax.random.split(rng, 5)
    p = {
        "router": jax.random.normal(k[0], (D, E), jnp.float32) * D**-0.5,
        "w_gate": jax.random.normal(k[1], (E, D, Fe), cfg.jdtype) * D**-0.5,
        "w_up": jax.random.normal(k[2], (E, D, Fe), cfg.jdtype) * D**-0.5,
        "w_down": jax.random.normal(k[3], (E, Fe, D), cfg.jdtype) * Fe**-0.5,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(k[4], cfg,
                               d_ff=cfg.n_shared_experts * cfg.d_ff_expert)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor)
    return max(int(cap), 8)


def moe_block(p, x, cfg: ModelConfig):
    """x [B,S,D] -> (out [B,S,D], aux_loss scalar).

    Dispatches to the shard_map expert-parallel path when a mesh with a
    "model" axis is active (production), else the single-device
    scatter/gather path (CPU tests; also the §Perf baseline — XLA's SPMD
    partitioner replicates the [E,C,D] dispatch buffers for the scatter
    formulation, ~6x the per-device footprint of explicit EP).
    """
    rules = shd.active()
    if rules is not None and rules.mp is not None \
            and cfg.n_experts % rules.axis_size("mp") == 0:
        return moe_block_ep(p, x, cfg)
    return moe_block_scatter(p, x, cfg)


def moe_block_scatter(p, x, cfg: ModelConfig):
    """Single-program scatter/gather dispatch (baseline)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    C = moe_capacity(cfg, N)
    xf = x.reshape(N, D)

    xf = shd.constrain(xf, ("dp", None))
    logits = (xf.astype(jnp.float32) @ p["router"])          # [N, E]
    logits = shd.constrain(logits, ("dp", None))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                      # [N, K]
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                             # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
        / N)
    density = jnp.zeros((E,), jnp.float32)
    for j in range(K):
        density += jnp.sum(jax.nn.one_hot(idx[:, j], E,
                                          dtype=jnp.float32), axis=0)
    density = density / (N * K)
    aux = jnp.sum(me * density) * E

    # position of each (token, choice) within its expert, choices serialized
    base = jnp.zeros((E,), jnp.int32)
    pos_js = []
    for j in range(K):
        oh = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)   # [N, E]
        oh = shd.constrain(oh, ("dp", None))
        cum = jnp.cumsum(oh, axis=0) - 1 + base[None, :]
        pos_js.append(jnp.take_along_axis(cum, idx[:, j:j + 1], 1)[:, 0])
        base = base + jnp.sum(oh, axis=0)
    pos = jnp.stack(pos_js, axis=1)                          # [N, K]
    keep = (pos < C)

    e_flat = shd.constrain(idx.reshape(-1), ("dp",))
    p_flat = shd.constrain(jnp.where(keep, pos, 0).reshape(-1), ("dp",))
    keep_f = keep.reshape(-1, 1).astype(x.dtype)
    upd = jnp.repeat(xf, K, axis=0) * keep_f                 # [N*K, D]
    upd = shd.constrain(upd, ("dp", None))

    buf = jnp.zeros((E, C, D), x.dtype).at[e_flat, p_flat].add(upd)
    buf = shd.constrain(buf, ("mp", "dp", None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])
    y_buf = shd.constrain(y_buf, ("mp", "dp", None))

    y = y_buf[e_flat, p_flat] * keep_f                       # [N*K, D]
    y = shd.constrain(y, ("dp", None))
    y = y.reshape(N, K, D)
    out = jnp.sum(y * gate[..., None].astype(x.dtype), axis=1)

    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], xf)
    return out.reshape(B, S, D), aux


def moe_block_ep(p, x, cfg: ModelConfig):
    """Expert-parallel MoE via shard_map + all_to_all (DESIGN.md §4).

    Mesh layout: tokens sharded over the dp axes, experts over "model"
    (weights replicated across dp). Each device routes its local tokens,
    packs a [mp, E_loc, C, D] send buffer, all_to_alls over the model
    axis, runs its local experts as batched matmuls, and all_to_alls the
    results back. Per-device buffers are O(local_tokens * top_k), never
    O(global tokens) — this is what the scatter path fails to achieve
    under automatic SPMD.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    rules = shd.active()
    mesh = rules.mesh
    mp_axis = rules.mp
    dp_axes = rules.dp
    mp_size = rules.axis_size("mp")
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // mp_size
    B, S, D = x.shape

    all_axes = tuple(dp_axes) + (mp_axis,)
    x_spec = P(rules.resolve("dp"), None, None)
    # experts: E over "model", D FSDP-sharded over dp (ZeRO-3) — gathered
    # per layer inside the shard_map body
    w_spec = P(mp_axis, rules.resolve("dp"), None)
    wd_spec = P(mp_axis, None, rules.resolve("dp"))

    def local_moe(xl, router, wg, wu, wd):
        # xl [B_loc, S, D] is dp-sharded but model-axis-REPLICATED; each
        # model column processes only its 1/mp slice of the local tokens
        # (padded to divisibility), then all-gathers the outputs — without
        # the slice every column would duplicate the other columns' work.
        # ZeRO-3 expert weights: gather the dp-sharded dim per layer
        if len(dp_axes) and wg.shape[1] != xl.shape[-1]:
            wg = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dp_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp_axes, axis=2, tiled=True)
        Bl, Sl, Dl = xl.shape
        N_full = Bl * Sl
        Np = -(-N_full // mp_size) * mp_size
        xf_full = xl.reshape(N_full, Dl)
        if Np != N_full:
            xf_full = jnp.pad(xf_full, ((0, Np - N_full), (0, 0)))
        Ns = Np // mp_size
        col_id = jax.lax.axis_index(mp_axis)
        xf = jax.lax.dynamic_slice_in_dim(xf_full, col_id * Ns, Ns, 0)
        N = Ns
        # local capacity with the configured slack factor
        C = max(int(-(-N * K // E) * cfg.capacity_factor), 8)

        logits = (xf.astype(jnp.float32) @ router)          # [N, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, K)                 # [N, K]
        gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)

        # aux load-balance loss (global mean via pmean)
        me = jnp.mean(probs, axis=0)
        density = jnp.zeros((E,), jnp.float32)
        for j in range(K):
            density += jnp.sum(jax.nn.one_hot(idx[:, j], E,
                                              dtype=jnp.float32), axis=0)
        density = density / (N * K)
        aux = jnp.sum(me * density) * E
        aux = jax.lax.pmean(aux, dp_axes + (mp_axis,))

        # position of each (token, choice) within its chosen expert
        base = jnp.zeros((E,), jnp.int32)
        pos_js = []
        for j in range(K):
            oh = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)
            cum = jnp.cumsum(oh, axis=0) - 1 + base[None, :]
            pos_js.append(jnp.take_along_axis(cum, idx[:, j:j+1], 1)[:, 0])
            base = base + jnp.sum(oh, axis=0)
        pos = jnp.stack(pos_js, 1)                          # [N, K]
        keep = pos < C
        col = idx // E_loc                                  # target column
        le = idx % E_loc                                    # local expert id
        p_safe = jnp.where(keep, pos, 0)
        keep_f = keep.reshape(-1, 1).astype(xl.dtype)

        send = jnp.zeros((mp_size, E_loc, C, Dl), xl.dtype)
        send = send.at[col.reshape(-1), le.reshape(-1),
                       p_safe.reshape(-1)].add(
            jnp.repeat(xf, K, axis=0) * keep_f)

        recv = jax.lax.all_to_all(send, mp_axis, 0, 0, tiled=False)
        # recv[i] = tokens column i routed to my experts
        buf = recv.transpose(1, 0, 2, 3).reshape(E_loc, mp_size * C, Dl)

        h = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd)

        y = y.reshape(E_loc, mp_size, C, Dl).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(y, mp_axis, 0, 0, tiled=False)
        # back[col, le, pos] = expert output for my token (col,le,pos)
        out_k = back[col.reshape(-1), le.reshape(-1),
                     p_safe.reshape(-1)] * keep_f           # [N*K, D]
        out = jnp.sum(out_k.reshape(N, K, Dl)
                      * gate[..., None].astype(xl.dtype), axis=1)
        # reassemble the full (model-axis-replicated) token set
        out_full = jax.lax.all_gather(out, mp_axis, axis=0, tiled=True)
        out_full = out_full[:N_full]
        return out_full.reshape(Bl, Sl, Dl), aux

    fn = shard_map(local_moe, mesh=mesh,
                   in_specs=(x_spec, P(), w_spec, w_spec, wd_spec),
                   out_specs=(x_spec, P()), check_rep=False)
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if cfg.n_shared_experts:
        out = out + mlp(p["shared"], x.reshape(-1, D)).reshape(B, S, D)
    return out, aux
