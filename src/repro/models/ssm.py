"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked SSD algorithm (block-decomposed: exact
quadratic attention within chunks + linear state passing across chunks);
decode is the O(1)-per-token recurrent update. n_groups=1 (B/C shared
across heads), head_dim P, state N.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as Lyr
from repro.models.common import ModelConfig
from repro.models.layers import init_rms, rms_norm


def init_mamba2(rng, cfg: ModelConfig):
    D, Din, N, H = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.ssm_heads
    conv_ch = Din + 2 * cfg.n_groups * N
    k = jax.random.split(rng, 4)
    return {
        "in_proj": jax.random.normal(
            k[0], (D, 2 * Din + 2 * cfg.n_groups * N + H),
            cfg.jdtype) * D**-0.5,
        "conv_w": jax.random.normal(k[1], (cfg.d_conv, conv_ch),
                                    cfg.jdtype) * 0.1,
        "conv_b": jnp.zeros((conv_ch,), cfg.jdtype),
        "A_log": jnp.zeros((H,), jnp.float32),      # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "norm": init_rms(Din),
        "out_proj": jax.random.normal(k[3], (Din, D),
                                      cfg.jdtype) * Din**-0.5,
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x [B,S,C], w [K,C] -> [B,S,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i:i + x.shape[1]] * w[i]
    return out + b


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, *, return_state=False):
    """Chunked SSD: one lax.scan over chunks fuses the intra-chunk
    quadratic part with the inter-chunk state recurrence, so the largest
    transient is the per-chunk [b,Q,Q,h] score tile (VMEM-friendly),
    never a whole-[b,c,q,k,h] tensor.

    xh [B,L,H,P]; dt [B,L,H] (post-softplus); A [H] (negative);
    Bm, Cm [B,L,N] (n_groups=1, broadcast over heads).
    Returns y [B,L,H,P] (and the final state [B,H,N,P] if requested).
    """
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    nc = L // Q
    # chunk-major for the scan: [nc, b, Q, ...]
    xc = xh.reshape(Bsz, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(Bsz, nc, Q, N).transpose(1, 0, 2, 3)
    qi = jnp.arange(Q)
    tril = (qi[:, None] >= qi[None, :])[None, :, :, None]  # [1,q,k,1]

    def body(state, inp):
        xq, dtq, Bq, Cq = inp                           # [b,Q,...]
        a = dtq * A[None, None, :]                      # [b,Q,h]
        cum = jnp.cumsum(a, axis=1)
        # intra-chunk
        CB = jnp.einsum("bqn,bkn->bqk", Cq, Bq,
                        preferred_element_type=jnp.float32)
        seg = cum[:, :, None, :] - cum[:, None, :, :]   # [b,q,k,h]
        decay = jnp.where(tril, jnp.exp(seg), 0.0)
        scores = CB[..., None] * decay * dtq[:, None]   # [b,q,k,h]
        y_diag = jnp.einsum("bqkh,bkhp->bqhp", scores,
                            xq.astype(jnp.float32))
        # contribution of the incoming state
        y_off = jnp.einsum("bqn,bqh,bhnp->bqhp", Cq.astype(jnp.float32),
                           jnp.exp(cum), state)
        # state update
        decay_out = jnp.exp(cum[:, -1:, :] - cum)       # [b,Q,h]
        inc = jnp.einsum("bkn,bkh,bkhp->bhnp", Bq.astype(jnp.float32),
                         decay_out * dtq, xq.astype(jnp.float32))
        chunk_decay = jnp.exp(cum[:, -1, :])            # [b,h]
        new_state = state * chunk_decay[..., None, None] + inc
        return new_state, (y_diag + y_off).astype(xh.dtype)

    init = jnp.zeros((Bsz, H, N, P), jnp.float32)
    if Lyr.unroll():
        state, ys = init, []
        for i in range(nc):
            state, yi = body(state, (xc[i], dtc[i], Bc[i], Cc[i]))
            ys.append(yi)
        final_state, yc = state, jnp.stack(ys)
    else:
        final_state, yc = jax.lax.scan(body, init, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, L, H, P)
    if return_state:
        return y, final_state
    return y


def ssd_decode_step(state, xt, dt, A, Bt, Ct):
    """One recurrent step. state [B,H,N,P]; xt [B,H,P]; dt [B,H];
    Bt, Ct [B,N]. Returns (new_state, y [B,H,P])."""
    da = jnp.exp(dt * A[None, :])                       # [B,H]
    inc = jnp.einsum("bn,bh,bhp->bhnp", Bt.astype(jnp.float32),
                     dt, xt.astype(jnp.float32))
    new_state = state * da[..., None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", Ct.astype(jnp.float32), new_state)
    return new_state, y.astype(xt.dtype)


def mamba2_block(p, x, cfg: ModelConfig, *, cache=None, pos=None):
    """Mamba2 block. x [B,S,D]. cache = {"conv": [B,d_conv-1,C],
    "ssm": [B,H,N,P]} for decode (S==1). Returns (out, new_cache)."""
    B, S, D = x.shape
    Din, N, H, P = cfg.d_inner, cfg.d_state, cfg.ssm_heads, cfg.ssm_head_dim
    G = cfg.n_groups

    proj = x @ p["in_proj"]                             # [B,S,...]
    z, xBC, dt_raw = jnp.split(
        proj, [Din, 2 * Din + 2 * G * N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])    # [B,S,H]
    A = -jnp.exp(p["A_log"])                            # [H]

    if cache is None or S > 1:
        conv_out = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        x_ssm, Bm, Cm = jnp.split(conv_out, [Din, Din + G * N], axis=-1)
        xh = x_ssm.reshape(B, S, H, P)
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                                     return_state=True)
        y = y + p["D_skip"][None, None, :, None] * xh
        new_cache = None
        if cache is not None:  # prefill: hand the final states to decode
            conv_state = jnp.pad(
                xBC, ((0, 0), (max(cfg.d_conv - 1 - S, 0), 0), (0, 0))
            )[:, -(cfg.d_conv - 1):]
            new_cache = {"conv": conv_state.astype(cfg.jdtype),
                         "ssm": final_state}
    else:  # decode
        conv_buf = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC],
                                   axis=1)              # [B,d_conv,C]
        conv_out = jnp.einsum("bkc,kc->bc", conv_buf, p["conv_w"]) \
            + p["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None]       # [B,1,C]
        x_ssm, Bm, Cm = jnp.split(conv_out, [Din, Din + G * N], axis=-1)
        xh = x_ssm.reshape(B, H, P)
        new_ssm, y = ssd_decode_step(cache["ssm"], xh, dt[:, 0], A,
                                     Bm[:, 0], Cm[:, 0])
        y = (y + p["D_skip"][None, :, None] * xh)[:, None]  # [B,1,H,P]
        new_cache = {"conv": conv_buf[:, 1:].astype(cfg.jdtype),
                     "ssm": new_ssm}

    y = y.reshape(B, S, Din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["scale"], cfg.norm_eps)
    return y @ p["out_proj"], new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int):
    conv_ch = cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
    return {"conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), cfg.jdtype),
            "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.d_state,
                              cfg.ssm_head_dim), jnp.float32)}
