from repro.configs.registry import (ARCH_IDS, SHAPES, cells, get_config,
                                    smoke_config)  # noqa: F401
