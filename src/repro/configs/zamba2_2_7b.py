"""Zamba2-2.7B [arXiv:2411.15242; hf]. Mamba2 backbone + weight-tied shared
attention block every 6 layers (simplified from per-use LoRA — DESIGN.md).
Sub-quadratic backbone -> runs long_500k."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000,
    d_state=64, expand=2, ssm_head_dim=64, ssm_chunk=256, attn_every=6,
    subquadratic=True,
)
