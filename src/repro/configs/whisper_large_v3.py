"""Whisper-large-v3 backbone [arXiv:2212.04356; unverified]. Enc-dec,
32+32 layers; conv/audio frontend is a STUB (input_specs provides 1500
precomputed frame embeddings)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, enc_len=1500,
)
