"""Mamba2-2.7B [arXiv:2405.21060; unverified]. SSD, attention-free,
state=128. Sub-quadratic decode -> runs long_500k."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, d_ff=0, vocab=50280,
    d_state=128, expand=2, ssm_head_dim=64, ssm_chunk=256,
    subquadratic=True,
)
