"""DeepSeek-67B [arXiv:2401.02954; hf]. Llama-arch, 95L GQA kv=8."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400,
)
