"""Architecture registry + assigned input shapes (see assignment block).

Every arch is selectable via --arch <id>; each (arch x shape) cell defines
one dry-run compile. ``long_500k`` runs only for sub-quadratic archs
(SSM/hybrid) per the assignment rules — skips documented in DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.common import ModelConfig

ARCH_IDS = [
    "deepseek_v2_lite_16b", "qwen3_moe_30b_a3b", "stablelm_1_6b",
    "deepseek_67b", "mistral_nemo_12b", "internlm2_1_8b", "mamba2_2_7b",
    "zamba2_2_7b", "whisper_large_v3", "internvl2_76b",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def cells(include_multipod: bool = False):
    """All live (arch, shape) dry-run cells, applying assignment skips."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in ["train_4k", "prefill_32k", "decode_32k", "long_500k"]:
            if s == "long_500k" and not cfg.subquadratic:
                continue  # needs sub-quadratic attention (DESIGN.md §3)
            out.append((a, s))
    return out


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, cfg.attn_every or 0),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128,
        vocab=256,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, d_ff_expert=32,
                  n_shared_experts=min(cfg.n_shared_experts, 1),
                  first_k_dense=min(cfg.first_k_dense, 1), d_ff_dense=128)
    if cfg.kv_lora_rank:
        kw.update(kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16,
                  v_head_dim=16, head_dim=0, n_kv_heads=0)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(d_state=16, ssm_head_dim=16, ssm_chunk=16, n_layers=4)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2, enc_len=32)
    if cfg.n_img_tokens:
        kw.update(n_img_tokens=8)
    return dataclasses.replace(cfg, **kw)
