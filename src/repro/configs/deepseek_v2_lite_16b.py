"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf]. MLA kv_lora=512, 64 routed
+ 2 shared experts top-6, first layer dense. (Assignment line also said
"160 routed" — see DESIGN.md §2 for the discrepancy note.)"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe_mla",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400,
    n_experts=64, n_shared_experts=2, top_k=6, d_ff_expert=1408,
    first_k_dense=1, d_ff_dense=10944,
    kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
)
