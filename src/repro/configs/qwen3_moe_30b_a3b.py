"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]. GQA kv=4, 128 experts top-8,
QK-norm, head_dim=128."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe_gqa",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab=151936,
    n_experts=128, top_k=8, d_ff_expert=768, qk_norm=True,
)
