"""InternVL2-76B backbone [arXiv:2404.16821; unverified]. InternLM2-76B-like
LM; InternViT frontend is a STUB (input_specs provides 256 patch
embeddings)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, n_img_tokens=256,
)
