"""Synthetic Azure-Functions-like invocation traces.

The Azure Functions 2019 dataset is not available offline, so we generate
traces calibrated to the marginals the paper reports (see DESIGN.md §2):

* per-minute invocation counts, 1440 minutes/day, 14 days (paper §IV.A);
* heterogeneity spanning ~8 orders of magnitude in invocation rate
  (Shahrad et al.);
* four ground-truth pattern families matching Table I: SPIKE (sudden
  bursts), PERIODIC (regular cycles), RAMP (gradual load changes),
  STATIONARY (stable with random noise).

Counts are Poisson-sampled from a pattern-specific rate curve, so windows
naturally contain noise, zeros, and bursts. Everything is seeded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.archetypes import Archetype

MINUTES_PER_DAY = 1440


@dataclasses.dataclass
class TraceSet:
    rates: np.ndarray          # [F, T] expected req/min (the latent rate)
    counts: np.ndarray         # [F, T] Poisson-sampled invocations/min
    pattern: np.ndarray        # [F] ground-truth Archetype id of generator
    base_rate: np.ndarray      # [F] mean req/min scale
    n_days: int

    @property
    def n_functions(self) -> int:
        return self.rates.shape[0]


def _periodic(rng, T, base):
    # Azure timer triggers skew to minute-scale periods (5-30 min crons);
    # longer periods legitimately label as other archetypes at 60-min
    # window scale.
    period = rng.choice([5, 10, 15, 20, 30, 60, 240],
                        p=[0.22, 0.24, 0.2, 0.14, 0.1, 0.05, 0.05])
    amp = rng.uniform(0.4, 0.95)
    phase = rng.uniform(0, 2 * np.pi)
    t = np.arange(T)
    wave = np.sin(2 * np.pi * t / period + phase)
    sharp = rng.uniform(1.0, 3.0)  # >1 sharpens peaks toward square/pulse
    wave = np.sign(wave) * np.abs(wave) ** (1.0 / sharp)
    rate = base * (1.0 + amp * wave)
    return np.maximum(rate, 0.0)


def _spike(rng, T, base):
    # quiet floor with a handful of large bursts per day
    floor = base * rng.uniform(0.02, 0.15)
    rate = np.full(T, floor)
    n_spikes = rng.poisson(6.0 * (T / MINUTES_PER_DAY)) + 1
    starts = rng.integers(0, T, size=n_spikes)
    for s in starts:
        height = base * rng.uniform(20.0, 300.0)
        dur = int(rng.integers(2, 12))
        decay = np.exp(-np.arange(dur) / max(dur / 3.0, 1.0))
        end = min(s + dur, T)
        rate[s:end] += height * decay[: end - s]
    return rate


def _ramp(rng, T, base):
    # piecewise-linear ramps over multi-hour segments (growth/migration)
    rate = np.empty(T)
    t0, level = 0, base * rng.uniform(0.3, 0.8)
    while t0 < T:
        seg = int(rng.integers(90, 360))
        direction = rng.choice([1.0, 1.0, 1.0, -0.7])  # mostly growth
        target = np.clip(level * rng.uniform(3.0, 8.0) ** direction,
                         0.1 * base, 100.0 * base)
        end = min(t0 + seg, T)
        rate[t0:end] = np.linspace(level, target, end - t0)
        level, t0 = target, end
    return rate


def _stationary(rng, T, base):
    cv = rng.uniform(0.05, 0.25)
    ar = rng.uniform(0.3, 0.8)  # mild AR(1) correlation
    noise = np.empty(T)
    noise[0] = 0.0
    eps = rng.normal(0, 1, T)
    for t in range(1, T):
        noise[t] = ar * noise[t - 1] + eps[t]
    noise /= max(noise.std(), 1e-9)
    return np.maximum(base * (1.0 + cv * noise), 0.0)


def _diurnal_burst(rng, T, base):
    # day-scale sinusoid (office-hours load) with random bursts riding on
    # top — the composite shape AAPAset's `diurnal_burst` scenario stresses
    phase = rng.uniform(0, 2 * np.pi)
    depth = rng.uniform(0.4, 0.9)
    t = np.arange(T)
    rate = base * (1.0 + depth * np.sin(2 * np.pi * t / MINUTES_PER_DAY
                                        + phase))
    n_bursts = rng.poisson(3.0 * (T / MINUTES_PER_DAY)) + 1
    for s in rng.integers(0, T, size=n_bursts):
        height = base * rng.uniform(10.0, 80.0)
        dur = int(rng.integers(3, 15))
        decay = np.exp(-np.arange(dur) / max(dur / 3.0, 1.0))
        end = min(s + dur, T)
        rate[s:end] += height * decay[: end - s]
    return np.maximum(rate, 0.0)


def _regime_switch(rng, T, base):
    # piecewise-constant demand regimes with abrupt multi-x level switches
    # every few hours (deploys / migrations / feature launches)
    rate = np.empty(T)
    t0, level = 0, base * rng.uniform(0.3, 1.0)
    while t0 < T:
        seg = int(rng.integers(180, 720))
        end = min(t0 + seg, T)
        cv = rng.uniform(0.03, 0.12)
        rate[t0:end] = level * (1.0 + cv * rng.normal(0, 1, end - t0))
        level = float(np.clip(level * rng.uniform(0.2, 5.0),
                              0.05 * base, 50.0 * base))
        t0 = end
    return np.maximum(rate, 0.0)


_GENERATORS = {
    Archetype.PERIODIC: _periodic,
    Archetype.SPIKE: _spike,
    Archetype.RAMP: _ramp,
    Archetype.STATIONARY_NOISY: _stationary,
}

# Function-level pattern mix chosen so the weak-supervision *window* label
# distribution lands near the paper's §V.A marginals (PERIODIC-heavy).
DEFAULT_MIX = {
    Archetype.PERIODIC: 0.70,
    Archetype.SPIKE: 0.14,
    Archetype.STATIONARY_NOISY: 0.08,
    Archetype.RAMP: 0.08,
}

# Scenario-diversity families (AAPAset registry variants). Each entry is
# (generator, ground-truth archetype tag for diagnostics, weight). The
# "default" family keeps the original generator/mix code path so existing
# seeds stay byte-identical.
FAMILY_SPECS: dict[str, list] = {
    "spike_heavy": [
        (_spike, Archetype.SPIKE, 0.50),
        (_diurnal_burst, Archetype.SPIKE, 0.15),
        (_periodic, Archetype.PERIODIC, 0.18),
        (_stationary, Archetype.STATIONARY_NOISY, 0.09),
        (_ramp, Archetype.RAMP, 0.08),
    ],
    "regime_switch": [
        (_regime_switch, Archetype.RAMP, 0.40),
        (_ramp, Archetype.RAMP, 0.10),
        (_stationary, Archetype.STATIONARY_NOISY, 0.15),
        (_periodic, Archetype.PERIODIC, 0.22),
        (_spike, Archetype.SPIKE, 0.13),
    ],
    "diurnal_burst": [
        (_diurnal_burst, Archetype.SPIKE, 0.45),
        (_periodic, Archetype.PERIODIC, 0.30),
        (_stationary, Archetype.STATIONARY_NOISY, 0.13),
        (_ramp, Archetype.RAMP, 0.12),
    ],
}
TRACE_FAMILIES = ("default", *FAMILY_SPECS)


def generate_traces(n_functions: int = 200, n_days: int = 14,
                    seed: int = 0, mix: dict | None = None,
                    family: str = "default") -> TraceSet:
    """Generate a seeded TraceSet. Base rates are log-uniform over ~5
    decades; combined with spike dynamic range this spans the ~8 orders of
    magnitude of the Azure characterization. `family` selects a scenario
    mix from ``FAMILY_SPECS`` ("default" = the paper-calibrated mix)."""
    if family not in TRACE_FAMILIES:
        raise ValueError(f"unknown trace family {family!r}; "
                         f"available: {list(TRACE_FAMILIES)}")
    rng = np.random.default_rng(seed)
    T = n_days * MINUTES_PER_DAY

    if family == "default":
        mix = mix or DEFAULT_MIX
        kinds = rng.choice(list(mix.keys()), size=n_functions,
                           p=np.array(list(mix.values())) / sum(mix.values()))
        base = 10.0 ** rng.uniform(-0.5, 3.2, size=n_functions)
        gens = [_GENERATORS[Archetype(int(k))] for k in kinds]
    else:
        if mix is not None:
            raise ValueError("mix= only applies to the default family")
        spec = FAMILY_SPECS[family]
        w = np.array([s[2] for s in spec])
        pick = rng.choice(len(spec), size=n_functions, p=w / w.sum())
        base = 10.0 ** rng.uniform(-0.5, 3.2, size=n_functions)
        gens = [spec[int(i)][0] for i in pick]
        kinds = np.array([int(spec[int(i)][1]) for i in pick])

    rates = np.empty((n_functions, T), np.float64)
    for i in range(n_functions):
        rates[i] = gens[i](rng, T, base[i])
    counts = rng.poisson(np.minimum(rates, 1e7)).astype(np.float32)
    return TraceSet(rates=rates.astype(np.float32), counts=counts,
                    pattern=np.asarray(kinds, np.int32),
                    base_rate=base.astype(np.float32), n_days=n_days)
