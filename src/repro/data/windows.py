"""Sliding-window dataset construction (paper §III.B.1, §IV.A).

60-minute windows, 10-minute stride; day-based splits: days 1-9 train,
10-11 validation, 12-14 test.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.azure_synth import MINUTES_PER_DAY, TraceSet

WINDOW_MIN = 60
STRIDE_MIN = 10


@dataclasses.dataclass
class WindowDataset:
    windows: np.ndarray    # [N, window] f32 counts (width need not be 60)
    func_id: np.ndarray    # [N] int32
    start_min: np.ndarray  # [N] int32 (global minute index of window start)
    pattern: np.ndarray    # [N] int32 generator ground truth (diagnostics)

    def __len__(self):
        return self.windows.shape[0]

    def day(self) -> np.ndarray:
        """1-based day index of each window (by window end)."""
        width = self.windows.shape[1]
        return ((self.start_min + width - 1) // MINUTES_PER_DAY) + 1


def make_windows(traces: TraceSet, *, window: int = WINDOW_MIN,
                 stride: int = STRIDE_MIN,
                 min_total_invocations: float = 1000.0) -> WindowDataset:
    """Slice every function's count series into sliding windows.

    Functions with fewer than `min_total_invocations` total invocations are
    filtered out (paper §IV.A preprocessing step 1).
    """
    counts = traces.counts
    active = counts.sum(axis=1) >= min_total_invocations
    counts = counts[active]
    patterns = traces.pattern[active]
    func_idx = np.nonzero(active)[0]

    F, T = counts.shape
    starts = np.arange(0, T - window + 1, stride, dtype=np.int32)
    # stride-window view: [F, n_starts, window]
    wins = np.lib.stride_tricks.sliding_window_view(
        counts, window, axis=1)[:, ::stride, :]
    n_starts = wins.shape[1]
    windows = wins.reshape(-1, window).astype(np.float32)
    func_id = np.repeat(func_idx, n_starts).astype(np.int32)
    start_min = np.tile(starts[:n_starts], F).astype(np.int32)
    pattern = np.repeat(patterns, n_starts).astype(np.int32)
    return WindowDataset(windows, func_id, start_min, pattern)


def day_split(ds: WindowDataset, train_days=(1, 9), val_days=(10, 11),
              test_days=(12, 14)):
    """Split by day-of-window-end. Returns dict of boolean masks."""
    d = ds.day()
    def mask(lo_hi):
        lo, hi = lo_hi
        return (d >= lo) & (d <= hi)
    return {"train": mask(train_days), "val": mask(val_days),
            "test": mask(test_days)}


def default_day_split(ds: WindowDataset, n_days: int):
    """Day split in the paper's 9/2/3 proportions, covering every day of
    the trace (at n_days=14 this is exactly the paper's 1-9 / 10-11 /
    12-14 split). Returns dict of boolean masks."""
    t_end = max(int(n_days * 9 / 14), 1)
    v_end = max(int(n_days * 11 / 14), t_end + 1)
    return day_split(ds, train_days=(1, t_end),
                     val_days=(t_end + 1, v_end),
                     test_days=(v_end + 1, n_days))


def subset(ds: WindowDataset, mask: np.ndarray) -> WindowDataset:
    return WindowDataset(ds.windows[mask], ds.func_id[mask],
                         ds.start_min[mask], ds.pattern[mask])
