"""Device-side evaluation metrics: EpisodeMetrics in jnp, vmap-able over
any leading batch axes, with P95/P99 from fixed log-spaced response
histograms so the whole thing accumulates *inside* the simulation scan.

Two paths, both pinned close to the NumPy oracle
(``repro.sim.metrics.aggregate``) by tests/test_evals.py:

* ``compute(out)`` / ``pooled(out)`` — post-hoc over MinuteOut arrays of
  shape [..., M] (or [..., W, M] pooled across workloads), fully
  vectorized: one scatter-add builds every lane's histogram.
* ``simulate_accum`` / ``make_metrics_simulator`` — fused: the metric
  accumulator (`MetricAccum`, a dozen scalars + one [bins] histogram)
  rides in the `lax.scan` carry next to the plant state, so per-minute
  outputs never materialize. This is what the `repro.evals.matrix`
  runner scans — memory is O(bins), not O(minutes), per cell.

Quantile approximation: per-minute mean responses land in log-spaced
bins spanning [resp_cap * 1e-5, resp_cap]; a quantile is reported as the
geometric midpoint of the bin where the cumulative served-weight first
reaches q * total. The guaranteed relative error is
``quantile_rel_bound(bins)`` (~0.6% at the default 1024 bins) plus
whatever the weighted-CDF tie-break moves between neighboring data
values — the parity test asserts the combined bound.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.sim.cluster import (MinuteOut, SimConfig, initial_state,
                               minute_step)

DEFAULT_BINS = 1024
_EDGE_LO_FRAC = 1e-5     # lowest histogram edge = resp_cap * this
EPS = 1e-9


class EpisodeMetrics(NamedTuple):
    """Field-for-field mirror of `repro.sim.metrics.EpisodeMetrics`, but a
    pytree of jnp arrays (any batch shape) instead of a float dataclass."""
    # performance
    slo_violation_rate: jax.Array
    cold_start_rate: jax.Array
    mean_response_ms: jax.Array
    p95_response_ms: jax.Array
    p99_response_ms: jax.Array
    # efficiency
    replica_minutes: jax.Array
    avg_cpu_util: jax.Array
    overprovision_rate: jax.Array
    # stability
    scaling_actions: jax.Array
    oscillations: jax.Array
    mean_action_interval_min: jax.Array
    total_requests: jax.Array

    def as_dict(self):
        return self._asdict()


class MetricAccum(NamedTuple):
    """In-scan accumulator. Everything is additive, so pooling workloads
    (or any batch axis) is a tree-sum over that axis before `finalize`."""
    served: jax.Array
    violated: jax.Array
    cold: jax.Array
    replica_sec: jax.Array
    resp_sum: jax.Array
    util_sum: jax.Array
    over_cnt: jax.Array      # minutes with util_mean < 0.5
    ups: jax.Array
    downs: jax.Array
    osc: jax.Array
    minutes: jax.Array
    hist: jax.Array          # [bins] served-weighted response histogram


def response_edges(bins: int = DEFAULT_BINS,
                   resp_cap: float = SimConfig().resp_cap_sec) -> jax.Array:
    """Log-spaced bin edges (seconds). Bin 0 is [0, edges[0]]; bin k>=1 is
    (edges[k-1], edges[k]]. resp is capped at resp_cap by the plant, so
    the top edge is exact."""
    return jnp.asarray(jnp.geomspace(resp_cap * _EDGE_LO_FRAC, resp_cap,
                                     bins), jnp.float32)


def quantile_rel_bound(bins: int = DEFAULT_BINS) -> float:
    """Guaranteed relative error of the histogram quantile vs the exact
    weighted quantile of the *binned values*: half a log-bin."""
    ratio = (1.0 / _EDGE_LO_FRAC) ** (1.0 / (bins - 1))
    return math.sqrt(ratio) - 1.0


def _representatives(edges: jax.Array) -> jax.Array:
    mids = jnp.sqrt(edges[:-1] * edges[1:])
    return jnp.concatenate([edges[:1], mids])


def _bin_index(resp: jax.Array, edges: jax.Array) -> jax.Array:
    return jnp.clip(jnp.searchsorted(edges, resp, side="left"),
                    0, edges.shape[0] - 1)


def accum_init(bins: int = DEFAULT_BINS) -> MetricAccum:
    z = jnp.float32(0.0)
    return MetricAccum(z, z, z, z, z, z, z, z, z, z, z,
                       jnp.zeros((bins,), jnp.float32))


def accum_update(acc: MetricAccum, m: MinuteOut,
                 edges: jax.Array) -> MetricAccum:
    """Fold one minute of plant output into the accumulator."""
    resp_mean = jnp.where(m.served > 0,
                          m.resp_sum / jnp.maximum(m.served, EPS), 0.0)
    return MetricAccum(
        served=acc.served + m.served,
        violated=acc.violated + m.violated,
        cold=acc.cold + m.cold_starts,
        replica_sec=acc.replica_sec + m.replica_seconds,
        resp_sum=acc.resp_sum + m.resp_sum,
        util_sum=acc.util_sum + m.util_mean,
        over_cnt=acc.over_cnt + (m.util_mean < 0.5).astype(jnp.float32),
        ups=acc.ups + m.ups,
        downs=acc.downs + m.downs,
        osc=acc.osc + m.oscillations,
        minutes=acc.minutes + 1.0,
        hist=acc.hist.at[_bin_index(resp_mean, edges)].add(m.served))


def accum_update_pooled(acc: MetricAccum, m: MinuteOut,
                        edges: jax.Array) -> MetricAccum:
    """Fold one minute of [..., W] plant output into a *pooled* [...]
    accumulator: the workload axis reduces inside the scan, so the carry
    is O(bins) per controller lane however large W grows — the streaming
    reduction the fleet runner (``repro.evals.fleet``) relies on.

    Equivalent to per-workload `accum_update` followed by a tree-sum
    over W, up to f32 summation order (the adds happen per minute here,
    per workload there)."""
    resp_mean = jnp.where(m.served > 0,
                          m.resp_sum / jnp.maximum(m.served, EPS), 0.0)
    idx = _bin_index(resp_mean, edges)                     # [..., W]
    lead = idx.shape[:-1]
    hist = (acc.hist.reshape(-1, acc.hist.shape[-1])
            .at[jnp.arange(math.prod(lead) if lead else 1)[:, None],
                idx.reshape(-1, idx.shape[-1])]
            .add(m.served.reshape(-1, idx.shape[-1]))
            .reshape(acc.hist.shape))
    return MetricAccum(
        served=acc.served + m.served.sum(-1),
        violated=acc.violated + m.violated.sum(-1),
        cold=acc.cold + m.cold_starts.sum(-1),
        replica_sec=acc.replica_sec + m.replica_seconds.sum(-1),
        resp_sum=acc.resp_sum + m.resp_sum.sum(-1),
        util_sum=acc.util_sum + m.util_mean.sum(-1),
        over_cnt=acc.over_cnt
        + (m.util_mean < 0.5).astype(jnp.float32).sum(-1),
        ups=acc.ups + m.ups.sum(-1),
        downs=acc.downs + m.downs.sum(-1),
        osc=acc.osc + m.oscillations.sum(-1),
        minutes=acc.minutes + float(idx.shape[-1]),
        hist=hist)


def _hist_quantile(hist: jax.Array, rep: jax.Array, q: float) -> jax.Array:
    """hist [..., bins] -> smallest-bin representative where the weighted
    CDF reaches q (inverted CDF, matching the host oracle)."""
    cum = jnp.cumsum(hist, -1)
    total = cum[..., -1]
    target = jnp.maximum(q * total, EPS)
    idx = jnp.clip(jnp.sum(cum < target[..., None], -1),
                   0, hist.shape[-1] - 1)
    return jnp.where(total > 0, rep[idx], 0.0)


def finalize(acc: MetricAccum, edges: jax.Array) -> EpisodeMetrics:
    """Accumulator -> EpisodeMetrics. Works on any batch shape as long as
    `hist` carries the bins axis last."""
    rep = _representatives(edges)
    arrived = jnp.maximum(acc.served, 1.0)
    actions = acc.ups + acc.downs
    return EpisodeMetrics(
        slo_violation_rate=acc.violated / arrived,
        cold_start_rate=acc.cold / arrived,
        mean_response_ms=1e3 * acc.resp_sum / arrived,
        p95_response_ms=1e3 * _hist_quantile(acc.hist, rep, 0.95),
        p99_response_ms=1e3 * _hist_quantile(acc.hist, rep, 0.99),
        replica_minutes=acc.replica_sec / 60.0,
        avg_cpu_util=acc.util_sum / jnp.maximum(acc.minutes, 1.0),
        overprovision_rate=acc.over_cnt / jnp.maximum(acc.minutes, 1.0),
        scaling_actions=actions,
        oscillations=acc.osc,
        mean_action_interval_min=acc.minutes / jnp.maximum(actions, 1.0),
        total_requests=acc.served)


# ------------------------------------------------------- post-hoc paths ----
def compute(out: MinuteOut, *, bins: int = DEFAULT_BINS,
            resp_cap: float = SimConfig().resp_cap_sec) -> EpisodeMetrics:
    """MinuteOut of [..., M] arrays -> EpisodeMetrics of [...] arrays.

    Each trailing-[M] trajectory aggregates independently (the device
    analogue of `sim.metrics.aggregate` per row / `per_workload`)."""
    edges = response_edges(bins, resp_cap)
    o = {k: jnp.asarray(v, jnp.float32) for k, v in out._asdict().items()}
    served = o["served"]
    lead, m = served.shape[:-1], served.shape[-1]

    resp_mean = jnp.where(served > 0,
                          o["resp_sum"] / jnp.maximum(served, EPS), 0.0)
    idx = _bin_index(resp_mean, edges).reshape(-1, m)
    lanes = jnp.arange(idx.shape[0])[:, None]
    hist = (jnp.zeros((idx.shape[0], bins), jnp.float32)
            .at[lanes, idx].add(served.reshape(-1, m))
            .reshape(lead + (bins,)))

    acc = MetricAccum(
        served=served.sum(-1),
        violated=o["violated"].sum(-1),
        cold=o["cold_starts"].sum(-1),
        replica_sec=o["replica_seconds"].sum(-1),
        resp_sum=o["resp_sum"].sum(-1),
        util_sum=o["util_mean"].sum(-1),
        over_cnt=(o["util_mean"] < 0.5).astype(jnp.float32).sum(-1),
        ups=o["ups"].sum(-1),
        downs=o["downs"].sum(-1),
        osc=o["oscillations"].sum(-1),
        minutes=jnp.full(lead, float(m), jnp.float32),
        hist=hist)
    return finalize(acc, edges)


def pooled(out: MinuteOut, **kw) -> EpisodeMetrics:
    """MinuteOut of [..., W, M] arrays pooled across workloads -> [...]
    (the device analogue of `aggregate(out, workload_axis=True)`)."""
    flat = jax.tree.map(lambda a: jnp.asarray(a).reshape(
        jnp.shape(a)[:-2] + (-1,)), out)
    return compute(flat, **kw)


#: Alias: compute() on [W, M] arrays IS the per-workload breakdown.
per_workload = compute


# ---------------------------------------------------------- fused paths ----
def simulate_accum(rates: jax.Array, controller, cfg: SimConfig,
                   edges: jax.Array) -> MetricAccum:
    """One workload, metrics accumulated in-scan: rates [M] ->
    MetricAccum. No per-minute output ever materializes."""
    def body(carry, rate):
        sim_carry, acc = carry
        sim_carry, m = minute_step(cfg, controller, sim_carry, rate)
        return (sim_carry, accum_update(acc, m, edges)), None

    carry0 = ((initial_state(controller, cfg), jnp.int32(0)),
              accum_init(edges.shape[0]))
    (_, acc), _ = jax.lax.scan(body, carry0,
                               jnp.asarray(rates, jnp.float32))
    return acc


def make_metrics_simulator(controller, cfg: SimConfig = SimConfig(), *,
                           bins: int = DEFAULT_BINS):
    """jit: rates [W, M] -> (pooled EpisodeMetrics scalars,
    per-workload EpisodeMetrics of [W] arrays), fused with the sim scan."""
    edges = response_edges(bins, cfg.resp_cap_sec)

    def run(rates):
        accs = jax.vmap(
            lambda r: simulate_accum(r, controller, cfg, edges))(rates)
        pool = jax.tree.map(lambda a: a.sum(0), accs)
        return finalize(pool, edges), finalize(accs, edges)

    return jax.jit(run)
