"""Content-addressed evaluation result artifacts + paper-table renderers.

A result card is addressed by the sha256 of its content key — the matrix
spec plus the classifier id — using the exact scheme of
``repro.aapaset.manifest`` (canonical-JSON sha256, atomic staged
publish). Re-running an identical spec is a cache hit; every benchmark
table names the run it came from by ``name-hash12``. Any change to the
plant, policies, or metric math that alters result bytes must bump
``repro.evals.matrix.SCHEMA_VERSION`` so stale cards invalidate.

Layout under ``experiments/evals/<name>-<hash12>/``:

* ``card.json``  — key, hash, axes, and pre-rendered markdown tables
  (Table IV-style policy comparison, Fig 2-style per-scenario breakdown,
  REI weight sensitivity).
* ``result.npz`` — every EvalResult array ([S, Z, F, P] pooled metrics,
  [S, Z, F, P, W] per-workload metrics, REI fields).

``save_card`` is the schema-light sibling for benches whose payload is a
plain dict (latency numbers, ablation variants) — same addressing, JSON
only.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil

import numpy as np

from repro.aapaset.manifest import hash_json, publish_dir, stage_dir
from repro.evals.matrix import EvalResult, MatrixSpec
from repro.evals import metrics as EM
from repro.evals import rei as ER

DEFAULT_ROOT = pathlib.Path("experiments/evals")


def card_hash(key: dict) -> str:
    return hash_json(key)


def result_dir(name: str, key: dict,
               root: pathlib.Path | str = DEFAULT_ROOT) -> pathlib.Path:
    return pathlib.Path(root) / f"{name}-{card_hash(key)}"


def is_cached(name: str, key: dict,
              root: pathlib.Path | str = DEFAULT_ROOT) -> bool:
    return (result_dir(name, key, root) / "card.json").exists()


def _result_arrays(result: EvalResult) -> dict[str, np.ndarray]:
    arrays = {}
    for prefix, tree in (("pooled", result.pooled),
                         ("perw", result.per_workload),
                         ("rei", result.rei)):
        for field, arr in tree._asdict().items():
            arrays[f"{prefix}.{field}"] = np.asarray(arr)
    return arrays


def save_result(spec: MatrixSpec, key: dict, result: EvalResult,
                root: pathlib.Path | str = DEFAULT_ROOT, *,
                replace: bool = False) -> dict:
    """Write card.json + result.npz; returns the card.

    `replace=True` (a forced re-run) clears any existing artifact at the
    address first — without it, publish_dir's same-address race rule
    would keep the old copy and silently drop the fresh one."""
    out = result_dir(spec.name, key, root)
    tmp = stage_dir(out)
    np.savez_compressed(tmp / "result.npz", **_result_arrays(result))
    card = {
        "schema": key.get("schema"),
        "key": key,
        "hash": card_hash(key),
        "axes": {"scenarios": spec.scenario_names(),
                 "seeds": list(spec.seeds),
                 "forecasters": list(spec.forecasters),
                 "policies": list(spec.policies),
                 "n_workloads": spec.n_workloads,
                 "minutes": spec.minutes},
        "spec": dataclasses.asdict(spec),
        "tables": {"policy_comparison": policy_table(result, spec),
                   "per_scenario": scenario_table(result, spec),
                   "rei_sensitivity": rei_sensitivity_table(result, spec)},
    }
    with open(tmp / "card.json", "w") as f:
        json.dump(card, f, indent=1, default=float)
    if replace:
        shutil.rmtree(out, ignore_errors=True)
    publish_dir(tmp, out, "card.json")
    return card


def load_result(name: str, key: dict,
                root: pathlib.Path | str = DEFAULT_ROOT
                ) -> tuple[EvalResult, dict]:
    out = result_dir(name, key, root)
    with open(out / "card.json") as f:
        card = json.load(f)
    with np.load(out / "result.npz") as z:
        fields = {k: z[k] for k in z.files}
    pick = lambda p, cls: cls(**{f: fields[f"{p}.{f}"]    # noqa: E731
                                 for f in cls._fields})
    return EvalResult(pick("pooled", EM.EpisodeMetrics),
                      pick("perw", EM.EpisodeMetrics),
                      pick("rei", ER.REIBreakdown)), card


def save_card(name: str, key: dict, payload: dict,
              root: pathlib.Path | str = DEFAULT_ROOT) -> dict:
    """Content-address a plain-dict bench payload (no arrays).

    Unlike matrix results, payloads here may carry run-varying numbers
    (wall-clock timings), so an existing card at the same address is
    replaced with the latest run rather than kept."""
    out = result_dir(name, key, root)
    tmp = stage_dir(out)
    card = {"key": key, "hash": card_hash(key), "payload": payload}
    with open(tmp / "card.json", "w") as f:
        json.dump(card, f, indent=1, default=float)
    shutil.rmtree(out, ignore_errors=True)
    publish_dir(tmp, out, "card.json")
    return card


# ------------------------------------------------------ table renderers ----
def _fp_labels(spec: MatrixSpec) -> list[tuple[int, int, str]]:
    """(f, p, label) per lane; forecaster shown only when it matters."""
    out = []
    for f, fc in enumerate(spec.forecasters):
        for p, pol in enumerate(spec.policies):
            label = pol
            if len(spec.forecasters) > 1 and \
                    registry_takes_forecaster(pol):
                label = f"{pol}[{fc}]"
            out.append((f, p, label))
    if len(spec.forecasters) > 1:
        # non-forecaster policies repeat identically per f lane: keep f=0
        seen, dedup = set(), []
        for f, p, label in out:
            if label in seen:
                continue
            seen.add(label)
            dedup.append((f, p, label))
        return dedup
    return out


def registry_takes_forecaster(policy: str) -> bool:
    from repro.scaling import registry
    return registry.spec(policy).takes_forecaster


def policy_table(result: EvalResult, spec: MatrixSpec) -> str:
    """Table IV-style policy comparison, averaged over scenarios x seeds."""
    m, r = result.pooled, result.rei
    lines = ["| policy | viol % | cold % | p95 ms | replica-min | "
             "actions | REI |",
             "|---|---|---|---|---|---|---|"]
    for f, p, label in _fp_labels(spec):
        def cell(a, f=f, p=p):
            return float(np.mean(np.asarray(a)[:, :, f, p]))
        lines.append(
            f"| {label} | {100 * cell(m.slo_violation_rate):.3f} "
            f"| {100 * cell(m.cold_start_rate):.3f} "
            f"| {cell(m.p95_response_ms):.1f} "
            f"| {cell(m.replica_minutes):.0f} "
            f"| {cell(m.scaling_actions):.0f} "
            f"| {cell(r.rei):.3f} |")
    return "\n".join(lines)


def scenario_table(result: EvalResult, spec: MatrixSpec,
                   baseline_policy: str = "hpa") -> str:
    """Fig 2-style breakdown: one row per scenario (use archetype_pure
    scenarios for the paper's per-archetype figure), SLO violations per
    policy plus the replica-minute ratio vs the baseline policy."""
    m = result.pooled
    labels = _fp_labels(spec)
    head = " | ".join(f"{label} viol%" for _, _, label in labels)
    lines = [f"| scenario | {head} | rep-min vs {baseline_policy} |",
             "|---" * (len(labels) + 2) + "|"]
    base = (spec.policies.index(baseline_policy)
            if baseline_policy in spec.policies else None)
    for s, sc_name in enumerate(spec.scenario_names()):
        cells = []
        for f, p, _ in labels:
            v = float(np.mean(np.asarray(m.slo_violation_rate)[s, :, f, p]))
            cells.append(f"{100 * v:.3f}")
        if base is None:
            ratio = "-"
        else:
            bm = float(np.mean(np.asarray(m.replica_minutes)[s, :, 0, base]))
            ratios = [float(np.mean(np.asarray(m.replica_minutes)[s, :, f, p]))
                      / max(bm, 1e-9) for f, p, _ in labels]
            ratio = " / ".join(f"{x:.2f}x" for x in ratios)
        lines.append(f"| {sc_name} | {' | '.join(cells)} | {ratio} |")
    return "\n".join(lines)


def rei_sensitivity_table(result: EvalResult, spec: MatrixSpec,
                          delta: float = 0.05) -> str:
    """REI weight-sensitivity (§V.D): per policy, REI range under the 6
    +/-delta weight perturbations, and whether the ranking ever flips."""
    m = result.pooled
    sens = ER.sensitivity(                       # [6, S, Z, F, P]
        m.slo_violation_rate, m.replica_minutes, m.scaling_actions,
        delta=delta, minutes=spec.minutes, n_workloads=spec.n_workloads)
    per = np.asarray(sens.rei).mean(axis=(1, 2))         # [6, F, P]
    labels = _fp_labels(spec)
    base = np.asarray(result.rei.rei).mean(axis=(0, 1))  # [F, P]
    base_rank = [label for _, _, label in
                 sorted(labels, key=lambda t: -base[t[0], t[1]])]
    flips = 0
    for k in range(per.shape[0]):
        rank = [label for _, _, label in
                sorted(labels, key=lambda t: -per[k, t[0], t[1]])]
        flips += rank != base_rank
    lines = [f"| policy | REI | min (+/-{delta}) | max (+/-{delta}) |",
             "|---|---|---|---|"]
    for f, p, label in labels:
        lines.append(f"| {label} | {base[f, p]:.3f} "
                     f"| {per[:, f, p].min():.3f} "
                     f"| {per[:, f, p].max():.3f} |")
    lines.append(f"\nranking: {' > '.join(base_rank)}; "
                 f"flips under perturbation: {flips}/{per.shape[0]}")
    return "\n".join(lines)
