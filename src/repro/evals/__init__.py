"""The unified evaluation plane: run -> aggregate -> compare as ONE
subsystem (paper §IV.D/§V), instead of per-benchmark aggregation loops.

* ``metrics``   — device-side EpisodeMetrics (jnp, vmap-able): in-scan
                  accumulators with fixed-bin histogram quantiles, plus
                  post-hoc ``compute``/``pooled`` over MinuteOut arrays.
                  ``repro.sim.metrics.aggregate`` is the NumPy oracle.
* ``rei``       — batched REI + weight sensitivity with scenario-aware
                  baselines (episode length x workload count).
* ``matrix``    — policies x forecasters x scenarios x seeds in one
                  compiled call; ``run(spec)`` is the front door.
* ``fleet``     — 10^5-10^6 workload lanes: W-chunked episodes with the
                  workload axis pooled in-scan (O(bins) accumulators),
                  one sharded dispatch or a streaming donated fold.
* ``artifacts`` — content-addressed result cards (same hashing scheme as
                  ``aapaset.manifest``) + paper-table renderers
                  (Table IV policy comparison, Fig 2 per-archetype
                  breakdown, §V.D REI sensitivity).
"""
from repro.evals import artifacts, fleet, matrix, metrics, rei  # noqa: F401,E501
from repro.evals.matrix import (EvalResult, MatrixRun,   # noqa: F401
                                MatrixSpec, run, smoke_spec, spec)
