"""Policies x forecasters x scenarios x seeds in ONE compiled call.

``spec(...)`` names an evaluation matrix (which policies, which
forecasters, which scenarios at which seeds, on which plant);
``make_runner(spec)`` compiles the whole grid into a single jitted
function — one control-period-blocked scan per controller lane (exactly
one `decide` per control step, the same O(P) layout as
``repro.scaling.batch.make_batch_simulator``) fused with the in-scan
metrics of ``repro.evals.metrics`` — per-minute outputs never
materialize, each cell returns EpisodeMetrics directly; and
``run(spec)`` is the front door: content-addressed against
``experiments/evals`` (same hashing scheme as ``aapaset.manifest``), so
re-running an identical spec is a cache hit on the result card.

    from repro.evals import matrix
    run = matrix.run(matrix.spec(
        "sweep", policies=("hpa", "aapa"), forecasters=("holt_winters",),
        scenarios=(("burst_storm", {}), ("idle_wake", {})), seeds=(0, 1)))
    run.result.pooled.slo_violation_rate        # [S, Z, F, P]
    run.card["hash"]                            # names the exact run

Policies that are not forecaster-aware (no `takes_forecaster` in their
registry spec) simply ignore the forecaster axis — lane (f, p) repeats
the same controller for every f, which keeps the result tensor dense.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.evals import metrics as EM
from repro.evals import rei as ER
from repro.scaling import batch, registry, scenarios
from repro.sim.cluster import SimConfig

SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class MatrixSpec:
    """One named evaluation matrix. Every field is part of the content
    key (including `bins`, which changes the reported quantiles)."""
    name: str
    policies: tuple[str, ...]
    forecasters: tuple[str, ...]
    scenarios: tuple[tuple[str, tuple[tuple[str, Any], ...]], ...]
    seeds: tuple[int, ...]
    n_workloads: int
    minutes: int
    sim: tuple[tuple[str, Any], ...] = ()
    bins: int = EM.DEFAULT_BINS

    def sim_config(self) -> SimConfig:
        return SimConfig(**dict(self.sim))

    def content_key(self) -> dict:
        return {"schema": SCHEMA_VERSION, "name": self.name,
                "policies": list(self.policies),
                "forecasters": list(self.forecasters),
                "scenarios": [[n, dict(kw)] for n, kw in self.scenarios],
                "seeds": list(self.seeds),
                "n_workloads": self.n_workloads, "minutes": self.minutes,
                "sim": dict(self.sim), "bins": self.bins}

    @property
    def shape(self) -> tuple[int, int, int, int]:
        return (len(self.scenarios), len(self.seeds),
                len(self.forecasters), len(self.policies))

    def scenario_names(self) -> list[str]:
        return [n if not kw else f"{n}:{dict(kw)}"
                for n, kw in self.scenarios]


def spec(name: str, *, policies: Sequence[str],
         forecasters: Sequence[str] = ("holt_winters",),
         scenarios: Sequence = (("archetype_mix", {}),),
         seeds: Sequence[int] = (0,), n_workloads: int = 8,
         minutes: int = 720, sim: dict | None = None,
         bins: int = EM.DEFAULT_BINS) -> MatrixSpec:
    """Normalizing constructor: scenario entries may be bare names or
    (name, kwargs) pairs; kwargs/sim dicts become sorted tuples so the
    spec is hashable and its content key canonical."""
    norm = []
    for entry in scenarios:
        if isinstance(entry, str):
            entry = (entry, {})
        sc_name, kw = entry
        norm.append((sc_name, tuple(sorted(dict(kw).items()))))
    return MatrixSpec(name=name, policies=tuple(policies),
                      forecasters=tuple(forecasters),
                      scenarios=tuple(norm), seeds=tuple(seeds),
                      n_workloads=int(n_workloads), minutes=int(minutes),
                      sim=tuple(sorted((sim or {}).items())), bins=bins)


def smoke_spec() -> MatrixSpec:
    """The CI tier-1 smoke matrix: 2 policies x 2 scenarios x 1 seed."""
    return spec("ci_smoke", policies=("hpa", "predictive"),
                scenarios=(("burst_storm", {}), ("idle_wake", {})),
                seeds=(0,), n_workloads=2, minutes=120)


class EvalResult(NamedTuple):
    """Structured result pytree of an evaluation matrix."""
    pooled: EM.EpisodeMetrics        # fields [S, Z, F, P]
    per_workload: EM.EpisodeMetrics  # fields [S, Z, F, P, W]
    rei: ER.REIBreakdown             # fields [S, Z, F, P]


class MatrixRun(NamedTuple):
    spec: MatrixSpec
    result: EvalResult               # numpy arrays
    card: dict
    cached: bool


def controllers(spec_: MatrixSpec, classify=None) -> list:
    """The F*P controller lanes, forecaster-major (lane = f * P + p)."""
    cfg = spec_.sim_config()
    ctrls = []
    for f in spec_.forecasters:
        for p in spec_.policies:
            kw = ({"forecaster": f}
                  if registry.spec(p).takes_forecaster else {})
            ctrls.append(registry.get_controller(p, cfg, classify=classify,
                                                 **kw))
    return ctrls


def build_rates(spec_: MatrixSpec) -> np.ndarray:
    """Materialize the scenario x seed workload tensor [S, Z, W, M]."""
    cfg = spec_.sim_config()
    rows = []
    for sc_name, kw in spec_.scenarios:
        per_seed = [scenarios.get(sc_name, n_workloads=spec_.n_workloads,
                                  minutes=spec_.minutes, seed=seed,
                                  cfg=cfg, **dict(kw)).rates
                    for seed in spec_.seeds]
        rows.append(np.stack(per_seed))
    rates = np.stack(rows).astype(np.float32)
    expect = spec_.shape[:2] + (spec_.n_workloads, spec_.minutes)
    if rates.shape != expect:
        raise ValueError(f"scenario tensor is {rates.shape}, expected "
                         f"{expect}; every scenario must honor "
                         "n_workloads/minutes")
    return rates


def _lane_runner(ctrls, cfg, edges, *, per_workload: bool = True,
                 shard: bool = True, telemetry: bool = False,
                 trace_lanes: int | None = None):
    """rates [W, M] -> MetricAccums of [P, W, ...] leaves: ONE blocked
    scan advances all P x W fused plant lanes with exactly one `decide`
    per controller per control step (`scaling.batch.make_batch_minute_
    step`), folding each minute into per-lane MetricAccums in the scan
    carry — the shared core of the matrix runner, the ad-hoc controller
    evaluator, and the fleet runner. Memory stays O(bins) per lane.

    With ``per_workload=False`` the workload axis reduces *inside* the
    scan (`EM.accum_update_pooled`) and the leaves are [P, ...]: the
    carry is O(P * bins) however large W grows — the fleet-scale mode.
    Under an active mesh the lane state and the per-workload accums are
    constrained over "dp"; the pooled accums are tiny and replicate (the
    cross-shard reduction happens in the scatter/sum ops themselves).

    ``telemetry=True`` rides the in-scan decision trace out as scan ys
    (NOT carry — the O(bins) carry bound holds at any fleet size) and
    returns ``(accums, ControlTrace)``: decisions leaves [M, H, P, K],
    minutes leaves [M, P, K], K = `trace_lanes` sampled lanes."""
    n_lanes = len(ctrls)
    step = batch.make_batch_minute_step(ctrls, cfg, shard=shard,
                                        telemetry=telemetry,
                                        trace_lanes=trace_lanes)
    if per_workload:
        fold = jax.vmap(jax.vmap(lambda a, m: EM.accum_update(a, m,
                                                              edges)))
    else:
        fold = lambda a, m: EM.accum_update_pooled(a, m, edges)  # noqa: E731

    def lanes(rates_w):
        W, _ = rates_w.shape
        lead = (n_lanes, W) if per_workload else (n_lanes,)
        acc0 = jax.tree.map(
            lambda a: jnp.broadcast_to(a, lead + a.shape),
            EM.accum_init(edges.shape[0]))

        def body(carry, rate_w):
            st, idx, acc = carry
            if telemetry:
                st, (m, ct) = step(st, idx, rate_w)
            else:
                st, m = step(st, idx, rate_w)
                ct = None
            acc = fold(acc, m)
            if shard and per_workload:
                acc = jax.tree.map(
                    lambda a: shd.constrain(a, (None, "dp")), acc)
            return (st, idx + 1, acc), ct

        (_, _, acc), ct = jax.lax.scan(
            body,
            (batch.batch_initial_state(ctrls, W, cfg), jnp.int32(0), acc0),
            rates_w.T)
        return (acc, ct) if telemetry else acc
    return lanes


def make_runner(spec_: MatrixSpec, classify=None, *,
                per_workload: bool = True, shard: bool = True,
                donate: bool = False, telemetry: bool = False,
                trace_lanes: int | None = None):
    """jit: rates [S, Z, W, M] -> (pooled EpisodeMetrics [S, Z, F, P],
    per-workload EpisodeMetrics [S, Z, F, P, W]). One compile, one
    dispatch for the whole matrix. Under an active `repro.dist.sharding`
    mesh the workload axis shards over "dp" (constrained on the input
    tensor and on every lane carry inside the scan).

    ``per_workload=False`` streams the workload reduction inside the
    scan (accum memory O(bins) per cell, independent of W) and returns
    ``(pooled, None)`` — the fleet-scale mode. ``donate=True`` donates
    the rates buffer to the call (fleet-sized inputs are not needed
    again after dispatch).

    ``telemetry=True`` also captures the in-scan decision trace (still
    ONE compile — the `_cache_size()==1` pin holds) and returns a
    3-tuple ``(pooled, per_workload, ControlTrace)`` with decisions
    leaves [S, Z, M, H, F, P, K] and minutes leaves [S, Z, M, F, P, K]
    (K = `trace_lanes` sampled workloads, all when None)."""
    cfg = spec_.sim_config()
    ctrls = controllers(spec_, classify)
    edges = EM.response_edges(spec_.bins, cfg.resp_cap_sec)
    _, _, f_axis, p_axis = spec_.shape

    over_seeds = jax.vmap(_lane_runner(ctrls, cfg, edges,
                                       per_workload=per_workload,
                                       shard=shard, telemetry=telemetry,
                                       trace_lanes=trace_lanes))
    over_scenarios = jax.vmap(over_seeds)        # [S, Z, L(, W), ...]

    def split_lanes(a, axis):
        return a.reshape(a.shape[:axis] + (f_axis, p_axis)
                         + a.shape[axis + 1:])

    def run_fn(rates):
        rates = jnp.asarray(rates, jnp.float32)
        if shard:
            rates = shd.constrain(rates, (None, None, "dp", None))
        out = over_scenarios(rates)
        accs, ct = out if telemetry else (out, None)
        accs = jax.tree.map(lambda a: split_lanes(a, 2), accs)
        if telemetry:
            # lane axis L -> (F, P): decisions [S, Z, M, H, L, K],
            # minutes [S, Z, M, L, K]
            ct = ct._replace(
                decisions=jax.tree.map(lambda a: split_lanes(a, 4),
                                       ct.decisions),
                minutes=jax.tree.map(lambda a: split_lanes(a, 3),
                                     ct.minutes))
        if not per_workload:
            pool = EM.finalize(accs, edges)
            return (pool, None, ct) if telemetry else (pool, None)
        per_w = EM.finalize(accs, edges)
        pool = EM.finalize(jax.tree.map(lambda a: a.sum(4), accs), edges)
        return (pool, per_w, ct) if telemetry else (pool, per_w)

    return jax.jit(run_fn, donate_argnums=(0,) if donate else ())


def make_controller_evaluator(ctrls: Sequence,
                              cfg: SimConfig = SimConfig(), *,
                              bins: int = EM.DEFAULT_BINS,
                              per_workload: bool = True,
                              shard: bool = True,
                              telemetry: bool = False,
                              trace_lanes: int | None = None):
    """Reusable jitted single-scenario evaluator for ad-hoc controllers
    (ablation variants, custom bands): rates [W, M] -> (pooled
    EpisodeMetrics [P], per-workload [P, W]). Keep the returned fn when
    sweeping many rate tensors — each call reuses the one compile.

    ``per_workload=False`` never materializes the [P, W, bins] accum
    tensor — the W reduction streams inside the scan and the result is
    ``(pooled [P], None)``. Use it for fleet-sized W (the host-parity
    tests at W >= 1e4 do).

    ``telemetry=True`` appends the in-scan ControlTrace (decisions
    leaves [M, H, P, K], minutes [M, P, K]) as a third element."""
    ctrls = list(ctrls)
    edges = EM.response_edges(bins, cfg.resp_cap_sec)
    lanes = _lane_runner(ctrls, cfg, edges, per_workload=per_workload,
                         shard=shard, telemetry=telemetry,
                         trace_lanes=trace_lanes)

    def run_fn(rates_w):
        out = lanes(rates_w)
        accs, ct = out if telemetry else (out, None)
        if not per_workload:
            pool = EM.finalize(accs, edges)
            return (pool, None, ct) if telemetry else (pool, None)
        pool = EM.finalize(jax.tree.map(lambda a: a.sum(1), accs), edges)
        per_w = EM.finalize(accs, edges)
        return (pool, per_w, ct) if telemetry else (pool, per_w)

    return jax.jit(run_fn)


def evaluate_controllers(ctrls: Sequence, rates,
                         cfg: SimConfig = SimConfig(), *,
                         bins: int = EM.DEFAULT_BINS,
                         per_workload: bool = True):
    """One-shot convenience wrapper over `make_controller_evaluator`."""
    return make_controller_evaluator(ctrls, cfg, bins=bins,
                                     per_workload=per_workload)(
        jnp.asarray(rates, jnp.float32))


def _execute(spec_: MatrixSpec, classify) -> EvalResult:
    pool, per_w = make_runner(spec_, classify)(build_rates(spec_))
    rei_b = ER.rei(pool.slo_violation_rate, pool.replica_minutes,
                   pool.scaling_actions, minutes=spec_.minutes,
                   n_workloads=spec_.n_workloads)
    to_np = lambda t: jax.tree.map(np.asarray, t)  # noqa: E731
    return EvalResult(to_np(pool), to_np(per_w), to_np(rei_b))


def run(spec_: MatrixSpec, *, classify=None, classifier_id: str = "",
        root=None, force: bool = False) -> MatrixRun:
    """The front door: evaluate the matrix, content-addressed.

    `classifier_id` must name the classifier whenever `classify` is
    passed (e.g. `trained.dataset_id`) — the callable itself cannot be
    hashed, so the id is what keys the artifact."""
    from repro.evals import artifacts
    if classify is not None and not classifier_id:
        raise ValueError("pass classifier_id= to content-address a run "
                         "with a custom classifier")
    key = dict(spec_.content_key(),
               classifier=classifier_id or "default_classify")
    root = artifacts.DEFAULT_ROOT if root is None else root
    if not force and artifacts.is_cached(spec_.name, key, root):
        result, card = artifacts.load_result(spec_.name, key, root)
        return MatrixRun(spec_, result, card, True)
    result = _execute(spec_, classify)
    card = artifacts.save_result(spec_, key, result, root, replace=force)
    return MatrixRun(spec_, result, card, False)
