"""Resource Efficiency Index (paper §III.D), batched.

    REI = alpha * S_SLO + beta * S_eff + gamma * S_stab

Operates on whole metric arrays (any broadcastable shape — e.g. the
[S, Z, F, P] pooled metrics out of ``repro.evals.matrix``) in jnp, so one
call scores every cell of an evaluation matrix.

Baselines are *scenario-aware*: S_eff normalizes pod-minutes by one pod
per workload for the episode length, and S_stab normalizes actions by the
paper's 10-per-workload-day prorated to the episode — instead of the
hardcoded one-pod-day constants. The paper's §V.D constants remain the
defaults (minutes=1440, n_workloads=1 reproduces them exactly; pinned by
tests/test_evals.py) and are exported as ``PAPER_BASELINE_*``.

``repro.core.rei`` keeps the scalar float dataclass front-end on top of
this module.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

DEFAULT_WEIGHTS = (0.5, 0.3, 0.2)
PAPER_BASELINE_POD_MINUTES = 1440.0   # one pod for one day (§V.D)
PAPER_BASELINE_ACTIONS = 10.0         # per workload-day
PAPER_DAY_MINUTES = 1440.0
EPS = 1e-9

SENSITIVITY_DELTAS = ((+1, -1, 0), (-1, +1, 0), (0, +1, -1),
                      (0, -1, +1), (+1, 0, -1), (-1, 0, +1))


class REIBreakdown(NamedTuple):
    s_slo: jax.Array
    s_eff: jax.Array
    s_stab: jax.Array
    rei: jax.Array


def scenario_baselines(minutes, n_workloads=1.0):
    """(baseline_pod_minutes, baseline_actions) for an episode of
    `minutes` over `n_workloads` workloads: one always-on pod per
    workload, and the paper's 10 actions per workload-day prorated."""
    scale = jnp.asarray(minutes, jnp.float32) / PAPER_DAY_MINUTES
    n = jnp.asarray(n_workloads, jnp.float32)
    return (PAPER_BASELINE_POD_MINUTES * scale * n,
            PAPER_BASELINE_ACTIONS * scale * n)


def rei(violation_rate, pod_minutes, scaling_actions, *,
        minutes=PAPER_DAY_MINUTES, n_workloads=1.0,
        baseline_pod_minutes=None, baseline_actions=None,
        weights=DEFAULT_WEIGHTS) -> REIBreakdown:
    """Batched REI; all inputs broadcast. Baselines default from the
    episode shape via `scenario_baselines`; pass `baseline_*` explicitly
    to override (e.g. the paper constants for §V.D)."""
    bpm, bact = scenario_baselines(minutes, n_workloads)
    if baseline_pod_minutes is not None:
        bpm = jnp.asarray(baseline_pod_minutes, jnp.float32)
    if baseline_actions is not None:
        bact = jnp.asarray(baseline_actions, jnp.float32)

    v = jnp.asarray(violation_rate, jnp.float32)
    pm = jnp.asarray(pod_minutes, jnp.float32)
    act = jnp.asarray(scaling_actions, jnp.float32)

    s_slo = jnp.clip(1.0 - v, 0.0, 1.0)
    s_eff = jnp.clip(1.0 / jnp.maximum(pm / jnp.maximum(bpm, EPS), EPS),
                     0.0, 1.0)
    s_stab = jnp.clip(1.0 / jnp.maximum(act / jnp.maximum(bact, EPS), EPS),
                      0.0, 1.0)
    w = jnp.asarray(weights, jnp.float32)
    return REIBreakdown(s_slo, s_eff, s_stab,
                        w[..., 0] * s_slo + w[..., 1] * s_eff
                        + w[..., 2] * s_stab)


def sensitivity(violation_rate, pod_minutes, scaling_actions, *,
                delta: float = 0.05, weights=DEFAULT_WEIGHTS,
                **kw) -> REIBreakdown:
    """REI under the paper's 6 weight perturbations of +/- delta (§V.D),
    batched: every returned field gains a leading [6] axis over
    `SENSITIVITY_DELTAS`."""
    a, b, g = weights
    ws = jnp.asarray([[a + da * delta, b + db * delta, g + dg * delta]
                      for da, db, dg in SENSITIVITY_DELTAS], jnp.float32)
    base = rei(violation_rate, pod_minutes, scaling_actions,
               weights=(1.0, 0.0, 0.0), **kw)   # scores only
    expand = (6,) + (1,) * jnp.ndim(base.s_slo)
    s = jax.tree.map(lambda x: jnp.broadcast_to(
        x, (6,) + jnp.shape(x)), REIBreakdown(
            base.s_slo, base.s_eff, base.s_stab, base.rei))
    w0, w1, w2 = (ws[:, i].reshape(expand) for i in range(3))
    return REIBreakdown(s.s_slo, s.s_eff, s.s_stab,
                        w0 * s.s_slo + w1 * s.s_eff + w2 * s.s_stab)
