"""Fleet-scale evaluation: 10^5-10^6 fused workload lanes per run.

The matrix runner materializes per-workload accumulators ([..., W, bins]
histograms) — fine for a grid cell, fatal for a region. This module is
the fleet front door over the same compiled core
(``matrix._lane_runner``): W-chunked episodes with the workload axis
reduced *inside* the scan (``metrics.accum_update_pooled``), so live
state is [P, w_chunk] plant lanes plus an O(P * bins) accumulator no
matter how large the fleet grows.

Two execution modes, one compiled chunk body:

* ``make_fleet_runner`` — ONE dispatch: rates [C, Wc, M] scanned over
  chunks inside jit, chunk accumulators tree-summed in the carry. The
  W=1e5 decade of BENCH_fleet.json runs this way (acceptance: peak host
  memory < 2x the W=1e4 run, because only the rates tensor grows).
* ``make_chunk_folder`` — streaming: a jitted (accum, chunk) -> accum
  fold with the accumulator donated, driven by a host generator
  (``rate_chunks`` here or ``aapaset.AAPAsetLoader.rate_chunks``). Rates
  never materialize beyond one chunk — this is the 1e6-lane mode.

Under an active ``repro.dist.sharding`` mesh the chunk's workload axis
shards over "dp" (each device advances its slice of every policy's
lanes); without a mesh everything is a no-op. ``run_fleet`` wraps either
mode with throughput + peak-RSS accounting and the pooled REI.
"""
from __future__ import annotations

import dataclasses
import resource
import time
from typing import Any, Iterator, NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.evals import metrics as EM
from repro.evals import rei as ER
from repro.evals.matrix import _lane_runner
from repro.scaling import registry, scenarios
from repro.sim.cluster import SimConfig


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """One fleet run: P policies x W workloads of one scenario family.

    `n_workloads` is the fleet size W; `w_chunk` lanes are live at a
    time (must divide W). Chunk c's workloads are drawn with a seed
    derived from (seed, c), so the fleet is deterministic and any chunk
    can be regenerated independently — the streaming mode depends on
    exactly that."""
    name: str
    policies: tuple[str, ...]
    forecaster: str = "holt_winters"
    scenario: str = "burst_storm"
    scenario_kw: tuple[tuple[str, Any], ...] = ()
    n_workloads: int = 1024
    w_chunk: int = 256
    minutes: int = 60
    seed: int = 0
    sim: tuple[tuple[str, Any], ...] = ()
    bins: int = EM.DEFAULT_BINS
    #: capture the decision trace for this many deterministically
    #: sampled lanes PER CHUNK (0 = telemetry off). The trace rides the
    #: chunk scan as ys, so the carry stays O(P * bins) at any W.
    trace_lanes: int = 0

    def __post_init__(self):
        if self.n_workloads % self.w_chunk:
            raise ValueError(f"w_chunk {self.w_chunk} must divide "
                             f"n_workloads {self.n_workloads}")

    @property
    def n_chunks(self) -> int:
        return self.n_workloads // self.w_chunk

    def sim_config(self) -> SimConfig:
        return SimConfig(**dict(self.sim))


def spec(name: str, *, policies: Sequence[str], **kw) -> FleetSpec:
    """Normalizing constructor (dict kwargs become sorted tuples)."""
    for key in ("scenario_kw", "sim"):
        if isinstance(kw.get(key), dict):
            kw[key] = tuple(sorted(kw[key].items()))
    return FleetSpec(name=name, policies=tuple(policies), **kw)


def controllers(spec_: FleetSpec, classify=None) -> list:
    cfg = spec_.sim_config()
    out = []
    for p in spec_.policies:
        fkw = ({"forecaster": spec_.forecaster}
               if registry.spec(p).takes_forecaster else {})
        out.append(registry.get_controller(p, cfg, classify=classify,
                                           **fkw))
    return out


def chunk_seed(seed: int, chunk: int) -> int:
    """Derived per-chunk scenario seed, stable across runs/processes."""
    return int(np.random.SeedSequence([seed, chunk]).generate_state(1)[0])


def chunk_rates(spec_: FleetSpec, chunk: int) -> np.ndarray:
    """Chunk `chunk`'s workloads: [w_chunk, minutes] float32."""
    sc = scenarios.get(spec_.scenario, n_workloads=spec_.w_chunk,
                       minutes=spec_.minutes,
                       seed=chunk_seed(spec_.seed, chunk),
                       cfg=spec_.sim_config(), **dict(spec_.scenario_kw))
    return np.asarray(sc.rates, np.float32)


def rate_chunks(spec_: FleetSpec) -> Iterator[np.ndarray]:
    """All C chunks in order — the streaming mode's default feed."""
    for c in range(spec_.n_chunks):
        yield chunk_rates(spec_, c)


def build_rates(spec_: FleetSpec) -> np.ndarray:
    """Materialize the whole fleet [C, w_chunk, minutes] for the
    one-dispatch mode. At W=1e5 x 60 min this is ~24 MB — the rates are
    the ONLY thing that grows with W; accumulators stay O(P * bins)."""
    return np.stack([chunk_rates(spec_, c) for c in range(spec_.n_chunks)])


def _pooled_acc0(n_lanes: int, bins: int):
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_lanes,) + a.shape),
                        EM.accum_init(bins))


def make_fleet_runner(spec_: FleetSpec, classify=None, *,
                      donate: bool = True):
    """jit: rates [C, Wc, M] -> pooled MetricAccum of [P] leaves, ONE
    dispatch. A lax.scan over chunks runs each [P, Wc] episode with the
    workload axis pooled in-scan, tree-summing chunk accumulators in the
    carry; the rates buffer is donated (it is dead after the scan reads
    it). The chunk's lane axis is constrained over "dp".

    With ``spec_.trace_lanes > 0`` the runner returns ``(accum,
    ControlTrace)`` — the trace of K sampled lanes per chunk rides the
    chunk scan as ys (decisions leaves [C, M, H, P, K], minutes
    [C, M, P, K]); the carry is unchanged."""
    cfg = spec_.sim_config()
    ctrls = controllers(spec_, classify)
    edges = EM.response_edges(spec_.bins, cfg.resp_cap_sec)
    telemetry = spec_.trace_lanes > 0
    lanes = _lane_runner(ctrls, cfg, edges, per_workload=False,
                         telemetry=telemetry,
                         trace_lanes=spec_.trace_lanes or None)

    def run(rates):
        rates = shd.constrain(jnp.asarray(rates, jnp.float32),
                              (None, "dp", None))

        def body(acc, chunk):
            if telemetry:
                acc_c, ct = lanes(chunk)
                return jax.tree.map(jnp.add, acc, acc_c), ct
            return jax.tree.map(jnp.add, acc, lanes(chunk)), None

        acc, ct = jax.lax.scan(body,
                               _pooled_acc0(len(ctrls), spec_.bins), rates)
        return (acc, ct) if telemetry else acc

    return jax.jit(run, donate_argnums=(0,) if donate else ())


def make_chunk_folder(spec_: FleetSpec, classify=None):
    """jit with a DONATED accumulator: (MetricAccum [P], rates [Wc, M])
    -> MetricAccum [P]. The streaming fold for generator-fed fleets —
    host memory is one chunk of rates + one O(P * bins) accumulator,
    so W is bounded by wall clock, not memory."""
    cfg = spec_.sim_config()
    ctrls = controllers(spec_, classify)
    edges = EM.response_edges(spec_.bins, cfg.resp_cap_sec)
    lanes = _lane_runner(ctrls, cfg, edges, per_workload=False)

    def fold(acc, chunk):
        chunk = shd.constrain(jnp.asarray(chunk, jnp.float32), ("dp", None))
        return jax.tree.map(jnp.add, acc, lanes(chunk))

    return jax.jit(fold, donate_argnums=(0,))


class FleetResult(NamedTuple):
    spec: FleetSpec
    pooled: EM.EpisodeMetrics    # [P] numpy, pooled over the whole fleet
    rei: ER.REIBreakdown         # [P] numpy
    meta: dict                   # wall_s, lane_minutes_per_sec, rss ...
    trace: Any = None            # ControlTrace (numpy) if trace_lanes > 0


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_fleet(spec_: FleetSpec, *, classify=None, stream: bool = False,
              chunks: Iterator[np.ndarray] | None = None,
              warmup: bool = False) -> FleetResult:
    """Evaluate the fleet; returns pooled metrics + REI + throughput.

    `stream=False`: one sharded dispatch over the materialized
    [C, Wc, M] tensor. `stream=True`: python loop over `chunks` (default
    `rate_chunks(spec_)`) through the donated-accumulator fold — pass a
    loader-backed generator (`AAPAsetLoader.rate_chunks`) to run real
    traces instead of synthetic scenarios. `warmup=True` (one-dispatch
    mode) runs the compiled call once before timing, so `wall_s` is the
    steady-state dispatch — the benchmark trajectory uses it; a cold
    call folds XLA compile time into the smallest decades."""
    cfg = spec_.sim_config()
    edges = EM.response_edges(spec_.bins, cfg.resp_cap_sec)
    P = len(spec_.policies)
    telemetry = spec_.trace_lanes > 0
    if telemetry and stream:
        raise ValueError("trace_lanes requires the one-dispatch mode; "
                         "the streaming fold keeps only the donated "
                         "accumulator (set stream=False)")
    t_build = time.perf_counter()
    ct = None
    if stream:
        fold = make_chunk_folder(spec_, classify)
        acc = _pooled_acc0(P, spec_.bins)
        t0 = time.perf_counter()
        n_chunks = 0
        for chunk in (rate_chunks(spec_) if chunks is None else chunks):
            acc = fold(acc, chunk)
            n_chunks += 1
        acc = jax.block_until_ready(acc)
        W = n_chunks * spec_.w_chunk
        dispatches = n_chunks
    else:
        rates = build_rates(spec_)
        run = make_fleet_runner(spec_, classify)
        if warmup:          # np input: each call transfers a fresh copy
            jax.block_until_ready(run(rates))
        t0 = time.perf_counter()
        out = jax.block_until_ready(run(rates))
        acc, ct = out if telemetry else (out, None)
        W, dispatches = spec_.n_workloads, 1
    wall = time.perf_counter() - t0
    pooled = jax.tree.map(np.asarray, EM.finalize(acc, edges))
    rei_b = jax.tree.map(np.asarray, ER.rei(
        pooled.slo_violation_rate, pooled.replica_minutes,
        pooled.scaling_actions, minutes=spec_.minutes, n_workloads=W))
    meta = {
        "workloads": W, "minutes": spec_.minutes, "policies": P,
        "w_chunk": spec_.w_chunk, "dispatches": dispatches,
        "stream": stream, "wall_s": wall, "warm": bool(warmup),
        "build_s": t0 - t_build,
        "lane_minutes_per_sec": P * W * spec_.minutes / max(wall, 1e-9),
        "minutes_per_sec": W * spec_.minutes / max(wall, 1e-9),
        "peak_rss_mb": _peak_rss_mb(),
        "n_devices": jax.device_count(),
        "mesh": (dict(shd.active().mesh.shape)
                 if shd.active() is not None else None)}
    if ct is not None:
        ct = jax.tree.map(np.asarray, ct)
    return FleetResult(spec_, pooled, rei_b, meta, ct)
