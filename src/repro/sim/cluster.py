"""Discrete-time Kubernetes cluster simulator as a jittable lax.scan.

Replaces the paper's SimPy simulator (§IV.B) with the same dynamics:

* 30-second pod startup (start pipeline),
* CPU-based scaling with 1-minute metric aggregation (EMA, tau = 60 s),
* FIFO request queue with a fluid M/D/c-style service model,
* 500 ms SLO; cold start = arrivals when zero pods are ready,
* requests uniform within each trace minute (paper's stated simplification).

Structure: outer `lax.scan` over minutes; inside each minute the 60 one-
second ticks are *control-period blocked*: `controller.decide` runs once
at each block head (the ticks where ``sec % control_interval_sec == 0``)
and the remaining ticks advance pure plant dynamics (pipeline pop, fluid
queue, EMA, limiter cooldown decay) in an unrolled loop that touches the
startup pipeline array only once per block. This is bit-exact with the
retained tick-level reference scan (``simulate_reference``) — which
keeps the seed's decide-every-tick-and-mask SEMANTICS — because the
masked decides were fully discarded and every masked action is an exact
float identity; pinned by the parity suite in tests/test_sim_blocked.py.
(The plant float ops themselves were reordered for speed and
FMA-stability in BOTH paths — div-form response terms, fold-based minute
aggregation, incremental pipe_sum — so absolute outputs drift at the
~1e-6-relative level vs the literal pre-blocking implementation, which
benchmarks/bench_sim.py reconstructs as its measured seed baseline.)
Remainder-block semantics for `control_interval_sec` values that don't
divide 60 (e.g. 7): the last block simply runs the leftover ``60 % ci``
ticks after its head, so the head schedule is identical to the
reference (`sec % ci == 0`).

Two plant-cost levers keep the blocked path hot-loop cheap:

* the minute aggregates fold tick-by-tick in the scan carry (strictly
  left-to-right, shared with the reference path — a post-hoc `jnp.sum`
  over materialized [60] outputs would fuse differently per path and
  break bitwise parity), so per-tick outputs never materialize;
* `SimState.pipe_sum` carries the startup-pipeline total incrementally
  (pop subtracts, scale-up adds, scale-down rescales — the identical
  update sequence in both paths), so plant ticks do O(1) work instead of
  an O(startup_sec) shift + reduction per tick.

On TPU the plant-only ticks of a block dispatch to the fused Pallas
kernel ``repro.kernels.plant_block`` (whole control period advanced in
VMEM); on CPU the blocked path below *is* the reference oracle the
kernel is property-tested against — the same kernel/ref dual-dispatch
pattern as `window_features` and `holt_winters`.

This module is the *plant*; the control plane lives in `repro.scaling`:
the Controller/Obs protocol and the cooldown semantics come from
`repro.scaling.api` (re-exported here for back-compat), the policies from
`repro.scaling.policies`, and batched policies-x-workloads evaluation
from `repro.scaling.batch`. `vmap` over workloads gives thousands of
simulated workload-days per minute of wall clock (vs the paper's 7 min
per workload-day).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace
from repro.scaling.api import (Controller, LimiterState, Obs,
                               apply_decision, limiter_init)

__all__ = ["Controller", "Obs", "SimConfig", "SimState", "MinuteOut",
           "advance_plant", "initial_state", "minute_step",
           "minute_step_reference", "plant_block_ref", "simulate",
           "simulate_reference", "make_simulator"]

EPSF = 1e-9


@dataclasses.dataclass(frozen=True)
class SimConfig:
    startup_sec: int = 30          # pod startup time (paper §IV.B)
    control_interval_sec: int = 15 # controller sync period (K8s default)
    # 1000 mCPU per replica (paper §IV.E), ~500 mCPU-seconds per request
    # -> 2 concurrent requests at 100 ms service time = 20 req/s. Chosen so
    # median functions need 1-3 replicas and peaks exercise scaling.
    rps_per_replica: float = 20.0
    service_sec: float = 0.1       # per-request service time
    slo_sec: float = 0.5           # SLO threshold (paper: 500 ms)
    max_replicas: float = 100.0
    initial_replicas: float = 2.0
    metric_tau_sec: float = 60.0   # 1-minute metric aggregation
    history_len: int = 60          # minutes of rate history kept for ctrl
    resp_cap_sec: float = 600.0    # cap reported response times (metrics)


class SimState(NamedTuple):
    ready: jax.Array         # f32 ready replicas
    pipeline: jax.Array      # [startup_sec] replicas starting (FIFO)
    pipe_sum: jax.Array      # f32 running total of `pipeline` (see module
    #                          docstring: updated incrementally, clamped
    #                          at 0, so plant ticks never reduce the array)
    queue: jax.Array         # f32 queued requests
    wait_sum: jax.Array      # f32 total request-seconds waited by the queue
    util_ema: jax.Array
    lim: LimiterState        # scale-down cooldown / direction tracking
    rate_history: jax.Array  # [history_len] per-minute arrival counts
    ctrl_state: Any


class MinuteOut(NamedTuple):
    served: jax.Array
    violated: jax.Array
    cold_starts: jax.Array
    replica_seconds: jax.Array
    queue_end: jax.Array
    resp_sum: jax.Array      # served-weighted response-time sum
    resp_max: jax.Array
    ups: jax.Array
    downs: jax.Array
    oscillations: jax.Array
    util_mean: jax.Array
    ready_mean: jax.Array


def _flow_tick(cfg: SimConfig, ready, queue, wait_sum, util_ema, arrivals):
    """The queue/response/EMA dynamics of one 1-second tick, after the
    startup-pipeline pop: shared by the control tick, the plant-only
    tick, the reference tick, and the Pallas kernel oracle."""
    # serve FIFO queue (fluid model with queue-age tracking)
    throughput = ready * cfg.rps_per_replica          # req/s
    work = queue + arrivals
    served = jnp.minimum(work, throughput)            # dt = 1 s
    new_queue = work - served
    # the standing queue ages 1 s; fresh arrivals have ~0 accumulated wait
    wait_aged = wait_sum + queue
    mean_age = wait_aged / jnp.maximum(work, EPSF)
    # served requests carry their accumulated wait; remaining queue keeps
    # a proportional share (uniform-age fluid approximation)
    wait_sum = wait_aged * new_queue / jnp.maximum(work, EPSF)
    # response = congestion-inflated service time (M/D/1-style 1/(1-u):
    # running hot costs latency) + accumulated wait + residual drain time
    util = served / jnp.maximum(throughput, EPSF)
    # every resp term is a division result (service/capped-headroom is the
    # M/D/1-style congestion inflation, capped at 20x service time): a
    # product feeding an add here would be an FMA-contraction candidate,
    # which LLVM applies per compiled program — the blocked and reference
    # paths compile to different programs, and a contracted-vs-plain resp
    # would break their bitwise parity (div-fed adds cannot contract)
    resp = (cfg.service_sec / jnp.maximum(1.0 - util, 0.05)
            + mean_age
            + (0.5 * new_queue) / jnp.maximum(throughput, EPSF))
    resp = jnp.minimum(resp, cfg.resp_cap_sec)
    resp = jnp.where(served > 0, resp, 0.0)
    violated = jnp.where(resp > cfg.slo_sec, served, 0.0)
    cold = jnp.where(ready < 0.5, arrivals, 0.0)      # zero ready pods
    # metrics (util is both the congestion input and the EMA input);
    # div-fed add for the same FMA-stability reason as resp
    util_ema = util_ema + (util - util_ema) / cfg.metric_tau_sec
    return new_queue, wait_sum, util_ema, served, violated, cold, resp, util


def _pop_pipeline(ready, pipeline, pipe_sum):
    """Pods finishing startup: pop slot 0, shift, keep the incremental
    pipeline total non-negative. Shape-agnostic: works on one lane
    (pipeline [S]) or a batch of lanes (pipeline [..., S])."""
    popped = pipeline[..., 0]
    ready = ready + popped
    pipeline = jnp.concatenate(
        [pipeline[..., 1:],
         jnp.zeros(pipeline.shape[:-1] + (1,), jnp.float32)], axis=-1)
    pipe_sum = jnp.maximum(pipe_sum - popped, 0.0)
    return ready, pipeline, pipe_sum


def _apply_scaling(ready, pipeline, pipe_sum, act):
    """Turn a ScaleAction into pipeline/ready updates: starts enter the
    pipeline tail; removals cancel starting pods first (proportional
    rescale), then ready pods. Shape-agnostic like `_pop_pipeline`."""
    pipeline = pipeline.at[..., -1].add(act.add)
    pipe_sum = pipe_sum + act.add
    n_start = pipe_sum
    from_pipe = jnp.minimum(act.remove, n_start)
    factor = 1.0 - from_pipe / jnp.maximum(n_start, EPSF)
    pipeline = pipeline * factor[..., None]
    pipe_sum = pipe_sum * factor
    ready = jnp.maximum(ready - (act.remove - from_pipe), 0.0)
    return ready, pipeline, pipe_sum


def _ctrl_tick(cfg: SimConfig, controller: Controller, state: SimState,
               arrivals: jax.Array, minute_idx: jax.Array, do_ctrl,
               telemetry: bool = False, head_sec=0.0):
    """One 1-second step with a controller decision. `do_ctrl` is the
    Python literal True on block heads (the blocked path — the masking
    folds away) or a traced mask (the reference path, which evaluates
    `decide` on every tick and discards the off-interval results).
    `telemetry` (static) additionally returns a DecisionRecord of this
    decision — the True branch only ADDS read-only ops, so the False
    path compiles to exactly the pre-telemetry program."""
    # 1. pods finishing startup
    ready, pipeline, pipe_sum = _pop_pipeline(
        state.ready, state.pipeline, state.pipe_sum)

    # 2./3. queue + metrics
    (queue, wait_sum, util_ema, served, violated, cold, resp,
     util) = _flow_tick(cfg, ready, state.queue, state.wait_sum,
                        state.util_ema, arrivals)

    # 4. control every control_interval_sec
    total = ready + pipe_sum
    obs = Obs(ready_total=total, ready=ready, util_ema=util_ema,
              queue=queue, rate_rps=arrivals,
              rate_history=state.rate_history, minute_idx=minute_idx)
    ctrl_state, desired, cool_req = controller.decide(state.ctrl_state, obs)
    if do_ctrl is not True:
        ctrl_state = jax.tree.map(
            lambda new, old: jnp.where(do_ctrl, new, old),
            ctrl_state, state.ctrl_state)
    desired_raw = desired
    desired = jnp.clip(desired, 0.0, cfg.max_replicas)

    lim, act = apply_decision(state.lim, total, desired, cool_req,
                              jnp.bool_(True) if do_ctrl is True else
                              do_ctrl, dt=1.0)
    ready_at_decision = ready
    ready, pipeline, pipe_sum = _apply_scaling(ready, pipeline, pipe_sum,
                                               act)

    new_state = SimState(ready=ready, pipeline=pipeline, pipe_sum=pipe_sum,
                         queue=queue, wait_sum=wait_sum, util_ema=util_ema,
                         lim=lim, rate_history=state.rate_history,
                         ctrl_state=ctrl_state)
    out = (served, violated, cold, ready + pipe_sum, resp,
           util, act.scale_up.astype(jnp.float32),
           act.scale_down.astype(jnp.float32), act.oscillation, ready)
    if not telemetry:
        return new_state, out
    exp = (controller.explain(state.ctrl_state, obs)
           if getattr(controller, "explain", None) is not None
           else obs_trace.explain_nan())
    rec = obs_trace.record(
        cfg, minute_idx=minute_idx, sec=head_sec, ready=ready_at_decision,
        total=total, queue=queue, util_ema=util_ema, rate_rps=arrivals,
        exp=exp, desired_raw=desired_raw, desired=desired,
        cooldown_req=cool_req, cooldown_before=state.lim.cooldown, act=act)
    return new_state, out, rec


# ------------------------------------------------- minute accumulation ----
#: Per-minute aggregates folded tick-by-tick in the scan carry (strictly
#: left-to-right over the 60 ticks) instead of reduced over materialized
#: [60] outputs — the blocked and reference paths share this fold, which
#: is what makes them bitwise identical: a post-hoc `jnp.sum` would fuse
#: differently over the two paths' output layouts.
def _resp_weight(resp, served):
    """`resp * served`, routed through a select so the accumulating add
    cannot FMA-contract with the product (contraction decisions differ
    between the blocked and reference compiled programs and would break
    their bitwise parity; a select operand is not a fusable product).
    Bit-identical to the bare product: resp is already 0 when served is."""
    return jnp.where(served > 0, resp * served, 0.0)


def _acc_init():
    z = jnp.float32(0.0)
    return (z,) * 11


def _acc_fold(acc, out):
    """Fold a control tick's 10-tuple (ups/downs/osc included)."""
    (served, violated, cold, total, resp, util, ups, downs, osc,
     ready) = out
    return (acc[0] + served, acc[1] + violated, acc[2] + cold,
            acc[3] + total, acc[4] + _resp_weight(resp, served),
            jnp.maximum(acc[5], resp), acc[6] + ups, acc[7] + downs,
            acc[8] + osc, acc[9] + util, acc[10] + ready)


def _acc_fold_plant(acc, served, violated, cold, total, resp, util, ready):
    """Fold a plant-only tick: ups/downs/oscillations are exactly 0.0 on
    non-control ticks, so skipping those adds is bit-exact."""
    return (acc[0] + served, acc[1] + violated, acc[2] + cold,
            acc[3] + total, acc[4] + _resp_weight(resp, served),
            jnp.maximum(acc[5], resp), acc[6], acc[7], acc[8],
            acc[9] + util, acc[10] + ready)


def _minute_out(acc, state: SimState) -> MinuteOut:
    return MinuteOut(
        served=acc[0], violated=acc[1], cold_starts=acc[2],
        replica_seconds=acc[3], queue_end=state.queue, resp_sum=acc[4],
        resp_max=acc[5], ups=acc[6], downs=acc[7], oscillations=acc[8],
        util_mean=acc[9] / 60.0, ready_mean=acc[10] / 60.0)


# --------------------------------------------------- plant-block advance ----
def plant_block_ref(cfg: SimConfig, ready, pipeline, queue, wait_sum,
                    util_ema, cooldown, pipe_sum, arrivals, *,
                    n_ticks: int):
    """Advance a lane-tile of plants `n_ticks` seconds with no control
    decisions: the pure-jnp oracle for the fused Pallas kernel
    (``repro.kernels.plant_block``). All state args are [B] (pipeline is
    [B, startup_sec]); `arrivals` is the per-lane per-second rate.

    Returns ``(state, ticks)`` where `state` is the tuple (ready,
    pipeline, queue, wait_sum, util_ema, cooldown, pipe_sum) after the
    block and `ticks` is the tuple (served, violated, cold,
    total_replicas, resp, util, ready) of [B, n_ticks] per-tick
    measurements."""
    def one_lane(r, p, q, w, u, c, ps, a):
        def body(carry, _):
            r, p, q, w, u, c, ps = carry
            popped = p[0]
            r = r + popped
            p = jnp.concatenate([p[1:], jnp.zeros((1,), jnp.float32)])
            ps = jnp.maximum(ps - popped, 0.0)
            q, w, u, served, violated, cold, resp, util = _flow_tick(
                cfg, r, q, w, u, a)
            c = jnp.maximum(c - 1.0, 0.0)
            return ((r, p, q, w, u, c, ps),
                    (served, violated, cold, r + ps, resp, util, r))
        return jax.lax.scan(body, (r, p, q, w, u, c, ps), None,
                            length=n_ticks)

    state, ticks = jax.vmap(one_lane)(
        jnp.asarray(ready, jnp.float32), jnp.asarray(pipeline, jnp.float32),
        jnp.asarray(queue, jnp.float32), jnp.asarray(wait_sum, jnp.float32),
        jnp.asarray(util_ema, jnp.float32),
        jnp.asarray(cooldown, jnp.float32),
        jnp.asarray(pipe_sum, jnp.float32),
        jnp.asarray(arrivals, jnp.float32))
    return state, ticks


#: Unroll plant blocks up to this many ticks (covers control intervals
#: through ~17 s, in particular the 15 s default); longer blocks scan
#: (see _plant_block docstring).
_UNROLL_MAX_TICKS = 16


def advance_plant(cfg: SimConfig, ready, pipeline, pipe_sum, queue,
                  wait_sum, util_ema, cooldown, acc, arrivals,
                  n_ticks: int):
    """`n_ticks` decision-free plant ticks with the minute accumulator
    folded along, on one lane or any batch of lanes (shape-agnostic like
    `_pop_pipeline`; the fused P x W batch in ``repro.scaling.batch``
    calls this on [L] fields). Returns (updated 7-field tuple, acc).

    Short blocks (the default 15 s control interval): an unrolled loop
    that reads `pipeline[..., k]` by static index and materializes the
    shifted pipeline array ONCE at block end — bit-identical to per-tick
    shifting, since the popped values and the incremental `pipe_sum`
    updates are the same floats; the n per-tick max(c-1, 0) cooldown
    decays likewise collapse to one exact step (nothing reads the
    limiter inside a block; c-1 is exact in the f32 range cooldowns live
    in, and both forms clamp to 0). Long blocks fall back to a per-tick
    lax.scan (same floats again; unrolling 40+ tick bodies was observed
    to perturb LLVM's scheduling of the resp math enough to cost
    last-ulp parity with the reference — and the decide savings already
    dominate at such long control intervals)."""
    S = pipeline.shape[-1]
    if n_ticks > _UNROLL_MAX_TICKS:
        def body(carry, _):
            ready, pipeline, pipe_sum, queue, wait_sum, util_ema, a = carry
            ready, pipeline, pipe_sum = _pop_pipeline(ready, pipeline,
                                                      pipe_sum)
            (queue, wait_sum, util_ema, served, violated, cold, resp,
             util) = _flow_tick(cfg, ready, queue, wait_sum, util_ema,
                                arrivals)
            a = _acc_fold_plant(a, served, violated, cold,
                                ready + pipe_sum, resp, util, ready)
            return (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
                    a), None
        carry0 = (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
                  acc)
        (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
         acc), _ = jax.lax.scan(body, carry0, None, length=n_ticks)
    else:
        pipe0 = pipeline
        for k in range(n_ticks):
            if k < S:
                popped = pipe0[..., k]
                ready = ready + popped
                # the shift-based form pops 0.0 once the pipeline has
                # fully drained (k >= S); max(ps - 0, 0) == ps for
                # ps >= 0, so the skip is exact
                pipe_sum = jnp.maximum(pipe_sum - popped, 0.0)
            (queue, wait_sum, util_ema, served, violated, cold, resp,
             util) = _flow_tick(cfg, ready, queue, wait_sum, util_ema,
                                arrivals)
            acc = _acc_fold_plant(acc, served, violated, cold,
                                  ready + pipe_sum, resp, util, ready)
        if n_ticks < S:
            pipeline = jnp.concatenate(
                [pipe0[..., n_ticks:],
                 jnp.zeros(pipe0.shape[:-1] + (n_ticks,), jnp.float32)],
                axis=-1)
        else:
            pipeline = jnp.zeros_like(pipe0)
    cooldown = jnp.maximum(cooldown - float(n_ticks), 0.0)
    return (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
            cooldown), acc


def _plant_block(cfg: SimConfig, state: SimState, acc,
                 arrivals: jax.Array, n_ticks: int, use_kernel: bool):
    """`n_ticks` plant-only ticks folded into the minute accumulator.
    CPU/ref: `advance_plant` (the kernel's parity oracle semantics).
    TPU: one fused `plant_tick_block` kernel call advancing the whole
    block in VMEM."""
    if not use_kernel:
        (ready, pipeline, pipe_sum, queue, wait_sum, util_ema,
         cool), acc = advance_plant(
            cfg, state.ready, state.pipeline, state.pipe_sum, state.queue,
            state.wait_sum, state.util_ema, state.lim.cooldown, acc,
            arrivals, n_ticks)
        state = state._replace(
            ready=ready, pipeline=pipeline, pipe_sum=pipe_sum,
            queue=queue, wait_sum=wait_sum, util_ema=util_ema,
            lim=LimiterState(cooldown=cool, last_dir=state.lim.last_dir))
        return state, acc

    from repro.kernels import ops
    (r, p, q, w, u, c, ps), ticks = ops.plant_tick_block(
        state.ready[None], state.pipeline[None], state.queue[None],
        state.wait_sum[None], state.util_ema[None],
        state.lim.cooldown[None], state.pipe_sum[None],
        jnp.asarray(arrivals)[None],
        n_ticks=n_ticks, rps_per_replica=cfg.rps_per_replica,
        service_sec=cfg.service_sec, slo_sec=cfg.slo_sec,
        resp_cap_sec=cfg.resp_cap_sec, metric_tau_sec=cfg.metric_tau_sec)
    state = state._replace(
        ready=r[0], pipeline=p[0], queue=q[0], wait_sum=w[0],
        util_ema=u[0], pipe_sum=ps[0],
        lim=LimiterState(cooldown=c[0], last_dir=state.lim.last_dir))
    served, violated, cold, total, resp, util, ready = (
        t[0] for t in ticks)                          # [n_ticks] each
    acc = (acc[0] + jnp.sum(served), acc[1] + jnp.sum(violated),
           acc[2] + jnp.sum(cold), acc[3] + jnp.sum(total),
           acc[4] + jnp.sum(resp * served),
           jnp.maximum(acc[5], jnp.max(resp)), acc[6], acc[7], acc[8],
           acc[9] + jnp.sum(util), acc[10] + jnp.sum(ready))
    return state, acc


def _block(cfg: SimConfig, controller: Controller, state: SimState, acc,
           arrivals, minute_idx, n_ticks: int, use_kernel: bool,
           telemetry: bool = False, head_sec=0.0):
    """One control period: decide at the head tick, then `n_ticks - 1`
    plant-only ticks, all folded into the minute accumulator."""
    if telemetry:
        state, head, rec = _ctrl_tick(cfg, controller, state, arrivals,
                                      minute_idx, True, telemetry=True,
                                      head_sec=head_sec)
        acc = _acc_fold(acc, head)
        if n_ticks > 1:
            state, acc = _plant_block(cfg, state, acc, arrivals,
                                      n_ticks - 1, use_kernel)
        return state, acc, rec
    state, head = _ctrl_tick(cfg, controller, state, arrivals, minute_idx,
                             True)
    acc = _acc_fold(acc, head)
    if n_ticks == 1:
        return state, acc
    return _plant_block(cfg, state, acc, arrivals, n_ticks - 1, use_kernel)


def _minute_blocked(cfg: SimConfig, controller: Controller, carry,
                    rate_this_min: jax.Array, use_kernel: bool = False,
                    telemetry: bool = False):
    """One minute = ceil(60/ci) control-period blocks + the minute-
    boundary controller hook. `decide` runs exactly once per block.

    With `telemetry` (static flag) the per-minute output becomes
    ``(MinuteOut, ControlTrace)`` where the trace's decisions stack the
    minute's H block-head DecisionRecords (H = #blocks, see
    ``repro.obs.trace.head_schedule``); the default path is untouched
    and compiles to the identical program."""
    state, minute_idx = carry
    arrivals_per_sec = rate_this_min / 60.0
    ci = max(min(int(cfg.control_interval_sec), 60), 1)
    n_full = 60 // ci                  # full-length blocks
    tail = 60 - n_full * ci            # remainder block (0 if ci | 60)

    acc = _acc_init()

    if telemetry:
        recs = []

        def block_body(carry, head_sec):
            st, a = carry
            st, a, rec = _block(cfg, controller, st, a, arrivals_per_sec,
                                minute_idx, ci, use_kernel, telemetry=True,
                                head_sec=head_sec)
            return (st, a), rec

        if n_full == 1:
            (state, acc), rec = block_body((state, acc), jnp.float32(0.0))
            recs.append(jax.tree.map(lambda x: x[None], rec))
        elif n_full:
            (state, acc), rec = jax.lax.scan(
                block_body, (state, acc),
                jnp.arange(n_full, dtype=jnp.float32) * ci)
            recs.append(rec)
        if tail:
            state, acc, rec = _block(cfg, controller, state, acc,
                                     arrivals_per_sec, minute_idx, tail,
                                     use_kernel, telemetry=True,
                                     head_sec=jnp.float32(n_full * ci))
            recs.append(jax.tree.map(lambda x: x[None], rec))
        decisions = (recs[0] if len(recs) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *recs))  # [H, ...]
        carry2, m = _finish_minute(cfg, controller, state, minute_idx,
                                   rate_this_min, acc)
        mt = obs_trace.MinuteTrace(
            rate=jnp.broadcast_to(rate_this_min, m.served.shape),
            served=m.served, violated=m.violated, queue_end=m.queue_end,
            ready_mean=m.ready_mean)
        return carry2, (m, obs_trace.ControlTrace(decisions=decisions,
                                                  minutes=mt))

    def block_body(carry, _):
        st, a = carry
        return _block(cfg, controller, st, a, arrivals_per_sec,
                      minute_idx, ci, use_kernel), None

    if n_full == 1:      # a length-1 scan only obscures the block body
        state, acc = _block(cfg, controller, state, acc, arrivals_per_sec,
                            minute_idx, ci, use_kernel)
    elif n_full:
        (state, acc), _ = jax.lax.scan(block_body, (state, acc), None,
                                       length=n_full)
    if tail:
        state, acc = _block(cfg, controller, state, acc, arrivals_per_sec,
                            minute_idx, tail, use_kernel)
    return _finish_minute(cfg, controller, state, minute_idx,
                          rate_this_min, acc)


def _finish_minute(cfg, controller, state, minute_idx, rate_this_min, acc):
    """Turn the tick-folded accumulator into MinuteOut and run the minute
    hook — shared verbatim by the blocked and reference paths so their
    aggregates stay bitwise identical."""
    m = _minute_out(acc, state)

    # minute boundary: push this minute's arrivals into history, run hook
    hist = jnp.concatenate(
        [state.rate_history[1:], rate_this_min[None]])
    ctrl_state = controller.on_minute(state.ctrl_state, hist,
                                      minute_idx + 1)
    state = state._replace(rate_history=hist, ctrl_state=ctrl_state)
    return (state, minute_idx + 1), m


# ----------------------------------------------------- reference path ----
def _minute_reference(cfg: SimConfig, controller: Controller, carry,
                      rate_this_min: jax.Array):
    """One minute = 60 ticks (decide evaluated on EVERY tick and masked
    by `do_ctrl` — the historical semantics the blocked scan is pinned
    bit-exact against) + the minute hook."""
    state, minute_idx = carry
    arrivals_per_sec = rate_this_min / 60.0

    def tick_body(carry, sec):
        st, a = carry
        do_ctrl = (sec % cfg.control_interval_sec) == 0
        st, out = _ctrl_tick(cfg, controller, st, arrivals_per_sec,
                             minute_idx, do_ctrl)
        return (st, _acc_fold(a, out)), None

    (state, acc), _ = jax.lax.scan(tick_body, (state, _acc_init()),
                                   jnp.arange(60, dtype=jnp.int32))
    return _finish_minute(cfg, controller, state, minute_idx,
                          rate_this_min, acc)


def initial_state(controller: Controller,
                  cfg: SimConfig = SimConfig()) -> SimState:
    """The t=0 plant state every simulation path starts from (the scan in
    `simulate` and the fused metrics scan in `repro.evals.metrics`)."""
    return SimState(
        ready=jnp.float32(cfg.initial_replicas),
        pipeline=jnp.zeros((cfg.startup_sec,), jnp.float32),
        pipe_sum=jnp.float32(0.0),
        queue=jnp.float32(0.0),
        wait_sum=jnp.float32(0.0),
        util_ema=jnp.float32(0.5),
        lim=limiter_init(),
        rate_history=jnp.zeros((cfg.history_len,), jnp.float32),
        ctrl_state=controller.init())


def _use_plant_kernel(explicit: bool | None) -> bool:
    """Dual dispatch shared with `window_features` / `holt_winters`: the
    fused Pallas block kernel on TPU, the blocked path (its oracle)
    elsewhere."""
    if explicit is None:
        return jax.default_backend() == "tpu"
    return explicit


def _use_decide_kernel(explicit: bool | None) -> bool:
    """Dispatch for the fused-decide episode kernel
    (``repro.kernels.episode_block``): same scheme as
    `_use_plant_kernel` — the kernel on TPU, the blocked scan below (its
    oracle) elsewhere. The off path is the unmodified blocked scan, so
    `decide_kernel=False` is bit-exact with not passing the flag at all
    on CPU (pinned in tests/test_decide_kernel.py)."""
    if explicit is None:
        return jax.default_backend() == "tpu"
    return explicit


def _reject_decide_kernel_telemetry():
    raise ValueError(
        "telemetry does not compose with decide_kernel: the fused "
        "episode kernel keeps decisions on-chip and never materializes "
        "DecisionRecords; run with decide_kernel=False, or capture "
        "sampled lanes via repro.evals.fleet (FleetSpec.trace_lanes)")


#: Public minute-granularity step: carry=(SimState, minute_idx) -> per-
#: minute MinuteOut scalars. `repro.evals.metrics` scans this directly to
#: accumulate metrics in-carry without materializing [M] outputs. This is
#: the control-period-blocked fast path; `minute_step_reference` keeps
#: the historical decide-every-tick semantics for parity pins.
minute_step = _minute_blocked
minute_step_reference = _minute_reference


def simulate(rates_per_min: jax.Array, controller: Controller,
             cfg: SimConfig = SimConfig(), *,
             plant_kernel: bool | None = None,
             decide_kernel: bool | None = None,
             telemetry: bool = False) -> MinuteOut:
    """Simulate one workload. rates_per_min [M] -> MinuteOut of [M] arrays.

    Control-period-blocked: `decide` runs once per control interval
    (bit-exact with `simulate_reference`, which evaluates it every tick).
    `plant_kernel=None` auto-selects the fused Pallas plant kernel on TPU
    for the decision-free ticks; `decide_kernel=None` auto-selects the
    *whole-episode* fused kernel (``repro.kernels.episode_block``) on
    TPU — plant ticks and `decide` both on-chip, this blocked scan as
    its dispatch oracle. `decide_kernel` subsumes `plant_kernel` when
    on.

    `telemetry=True` (static) additionally captures the in-scan decision
    trace and returns ``(MinuteOut, ControlTrace)`` with decisions
    leaves [M, H] (H block heads per minute) and minutes leaves [M];
    the default path compiles to the identical pre-telemetry program.
    Incompatible with `decide_kernel` (decisions stay on-chip there).
    """
    if _use_decide_kernel(decide_kernel):
        if telemetry:
            _reject_decide_kernel_telemetry()
        from repro.kernels import ops
        out = ops.episode_block(rates_per_min.astype(jnp.float32)[None],
                                controller, cfg)
        return jax.tree.map(lambda a: a[0], out)
    use_kernel = _use_plant_kernel(plant_kernel)
    (state, _), out = jax.lax.scan(
        partial(_minute_blocked, cfg, controller, use_kernel=use_kernel,
                telemetry=telemetry),
        (initial_state(controller, cfg), jnp.int32(0)),
        rates_per_min.astype(jnp.float32))
    return out


def simulate_reference(rates_per_min: jax.Array, controller: Controller,
                       cfg: SimConfig = SimConfig()) -> MinuteOut:
    """The retained seed-semantics scan (decide evaluated on all 60 ticks
    per minute, masked off-interval). Slow; exists as the parity oracle
    for `simulate` and the blocked-vs-seed benchmark baseline."""
    (state, _), out = jax.lax.scan(
        partial(_minute_reference, cfg, controller),
        (initial_state(controller, cfg), jnp.int32(0)),
        rates_per_min.astype(jnp.float32))
    return out


def make_simulator(controller: Controller, cfg: SimConfig = SimConfig(), *,
                   plant_kernel: bool | None = None,
                   decide_kernel: bool | None = None,
                   w_chunk: int | None = None, donate: bool = False,
                   telemetry: bool = False):
    """jit(vmap(simulate)): rates [W, M] -> MinuteOut of [W, M] arrays.

    Fleet knobs (mirroring `repro.scaling.batch.make_batch_simulator`):
    `w_chunk` scans over chunks of the workload axis inside the one
    dispatch so live plant state is [w_chunk] however large W grows
    (chunks are independent episodes; requires W % w_chunk == 0);
    `donate` donates the rates buffer to the call, so a fleet-sized
    input tensor never double-buffers against the outputs. `telemetry`
    returns ``(MinuteOut [W, M], ControlTrace)`` with decisions leaves
    [W, M, H] and minutes leaves [W, M].

    `decide_kernel` (auto on TPU, like `plant_kernel`) routes whole
    episodes through the fused-decide Pallas kernel — the W lanes ARE
    the kernel's lane tiles, so the vmap disappears and the episode is
    one kernel launch per w-chunk inside the same single compile
    (`_cache_size()` stays 1, pinned in tests/test_decide_kernel.py).
    Incompatible with `telemetry` (decisions stay on-chip)."""
    if _use_decide_kernel(decide_kernel):
        if telemetry:
            _reject_decide_kernel_telemetry()
        from repro.kernels import ops
        fn = lambda rates: ops.episode_block(  # noqa: E731
            rates.astype(jnp.float32), controller, cfg)
    else:
        fn = jax.vmap(lambda r: simulate(r, controller, cfg,
                                         plant_kernel=plant_kernel,
                                         decide_kernel=False,
                                         telemetry=telemetry))

    def run(rates):
        W, M = rates.shape
        if w_chunk is None or w_chunk >= W:
            return fn(rates)
        if W % w_chunk:
            raise ValueError(f"w_chunk {w_chunk} must divide W {W}")
        chunked = rates.reshape(W // w_chunk, w_chunk, M)
        _, out = jax.lax.scan(lambda c, r: (c, fn(r)), 0, chunked)
        return jax.tree.map(lambda a: a.reshape((W,) + a.shape[2:]), out)

    return jax.jit(run, donate_argnums=(0,) if donate else ())
