"""Discrete-time Kubernetes cluster simulator as a jittable lax.scan.

Replaces the paper's SimPy simulator (§IV.B) with the same dynamics:

* 30-second pod startup (start pipeline),
* CPU-based scaling with 1-minute metric aggregation (EMA, tau = 60 s),
* FIFO request queue with a fluid M/D/c-style service model,
* 500 ms SLO; cold start = arrivals when zero pods are ready,
* requests uniform within each trace minute (paper's stated simplification).

Structure: outer `lax.scan` over minutes, inner `lax.scan` over 1 s ticks.
This module is the *plant*; the control plane lives in `repro.scaling`:
the Controller/Obs protocol and the cooldown semantics come from
`repro.scaling.api` (re-exported here for back-compat), the policies from
`repro.scaling.policies`, and batched policies-x-workloads evaluation
from `repro.scaling.batch`. `vmap` over workloads gives thousands of
simulated workload-days per minute of wall clock (vs the paper's 7 min
per workload-day).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.scaling.api import (Controller, LimiterState, Obs,
                               apply_decision, limiter_init)

__all__ = ["Controller", "Obs", "SimConfig", "SimState", "MinuteOut",
           "initial_state", "minute_step", "simulate", "make_simulator"]

EPSF = 1e-9


@dataclasses.dataclass(frozen=True)
class SimConfig:
    startup_sec: int = 30          # pod startup time (paper §IV.B)
    control_interval_sec: int = 15 # controller sync period (K8s default)
    # 1000 mCPU per replica (paper §IV.E), ~500 mCPU-seconds per request
    # -> 2 concurrent requests at 100 ms service time = 20 req/s. Chosen so
    # median functions need 1-3 replicas and peaks exercise scaling.
    rps_per_replica: float = 20.0
    service_sec: float = 0.1       # per-request service time
    slo_sec: float = 0.5           # SLO threshold (paper: 500 ms)
    max_replicas: float = 100.0
    initial_replicas: float = 2.0
    metric_tau_sec: float = 60.0   # 1-minute metric aggregation
    history_len: int = 60          # minutes of rate history kept for ctrl
    resp_cap_sec: float = 600.0    # cap reported response times (metrics)


class SimState(NamedTuple):
    ready: jax.Array         # f32 ready replicas
    pipeline: jax.Array      # [startup_sec] replicas starting (FIFO)
    queue: jax.Array         # f32 queued requests
    wait_sum: jax.Array      # f32 total request-seconds waited by the queue
    util_ema: jax.Array
    lim: LimiterState        # scale-down cooldown / direction tracking
    rate_history: jax.Array  # [history_len] per-minute arrival counts
    ctrl_state: Any


class MinuteOut(NamedTuple):
    served: jax.Array
    violated: jax.Array
    cold_starts: jax.Array
    replica_seconds: jax.Array
    queue_end: jax.Array
    resp_sum: jax.Array      # served-weighted response-time sum
    resp_max: jax.Array
    ups: jax.Array
    downs: jax.Array
    oscillations: jax.Array
    util_mean: jax.Array
    ready_mean: jax.Array


def _tick(cfg: SimConfig, controller: Controller, state: SimState,
          arrivals: jax.Array, sec_in_min: jax.Array,
          minute_idx: jax.Array):
    """One 1-second step. Returns (state, per-tick outputs)."""
    # 1. pods finishing startup
    ready = state.ready + state.pipeline[0]
    pipeline = jnp.concatenate(
        [state.pipeline[1:], jnp.zeros((1,), jnp.float32)])

    # 2. serve FIFO queue (fluid model with queue-age tracking)
    throughput = ready * cfg.rps_per_replica          # req/s
    work = state.queue + arrivals
    served = jnp.minimum(work, throughput)            # dt = 1 s
    queue = work - served
    # the standing queue ages 1 s; fresh arrivals have ~0 accumulated wait
    wait_aged = state.wait_sum + state.queue
    mean_age = wait_aged / jnp.maximum(work, EPSF)
    # served requests carry their accumulated wait; remaining queue keeps
    # a proportional share (uniform-age fluid approximation)
    wait_sum = wait_aged * queue / jnp.maximum(work, EPSF)
    # response = congestion-inflated service time (M/D/1-style 1/(1-u):
    # running hot costs latency) + accumulated wait + residual drain time
    util_now = served / jnp.maximum(throughput, EPSF)
    congest = 1.0 / jnp.maximum(1.0 - util_now, 0.05)  # capped at 20x
    resp = (cfg.service_sec * congest + mean_age
            + 0.5 * queue / jnp.maximum(throughput, EPSF))
    resp = jnp.minimum(resp, cfg.resp_cap_sec)
    resp = jnp.where(served > 0, resp, 0.0)
    violated = served * (resp > cfg.slo_sec)
    cold = arrivals * (ready < 0.5)                   # zero ready pods

    # 3. metrics
    util_inst = served / jnp.maximum(throughput, EPSF)
    util_ema = state.util_ema + (1.0 / cfg.metric_tau_sec) * (
        util_inst - state.util_ema)

    # 4. control every control_interval_sec
    total = ready + jnp.sum(pipeline)
    do_ctrl = (sec_in_min % cfg.control_interval_sec) == 0
    obs = Obs(ready_total=total, ready=ready, util_ema=util_ema,
              queue=queue, rate_rps=arrivals,
              rate_history=state.rate_history, minute_idx=minute_idx)
    ctrl_state_new, desired, cool_req = controller.decide(
        state.ctrl_state, obs)
    ctrl_state = jax.tree.map(
        lambda new, old: jnp.where(do_ctrl, new, old),
        ctrl_state_new, state.ctrl_state)
    desired = jnp.clip(desired, 0.0, cfg.max_replicas)

    lim, act = apply_decision(state.lim, total, desired, cool_req,
                              do_ctrl, dt=1.0)
    pipeline = pipeline.at[-1].add(act.add)

    # cancel starting pods first, then ready pods
    n_start = jnp.sum(pipeline)
    from_pipe = jnp.minimum(act.remove, n_start)
    pipeline = pipeline * (1.0 - from_pipe / jnp.maximum(n_start, EPSF))
    ready = jnp.maximum(ready - (act.remove - from_pipe), 0.0)

    new_state = SimState(ready=ready, pipeline=pipeline, queue=queue,
                         wait_sum=wait_sum, util_ema=util_ema,
                         lim=lim, rate_history=state.rate_history,
                         ctrl_state=ctrl_state)
    out = (served, violated, cold, ready + jnp.sum(pipeline), resp,
           util_inst, act.scale_up.astype(jnp.float32),
           act.scale_down.astype(jnp.float32), act.oscillation, ready)
    return new_state, out


def _minute(cfg: SimConfig, controller: Controller, carry,
            rate_this_min: jax.Array):
    """One minute = 60 ticks + minute-boundary controller hook."""
    state, minute_idx = carry
    arrivals_per_sec = rate_this_min / 60.0

    def tick_body(st, sec):
        return _tick(cfg, controller, st, arrivals_per_sec, sec, minute_idx)

    state, outs = jax.lax.scan(tick_body, state,
                               jnp.arange(60, dtype=jnp.int32))
    (served, violated, cold, total_reps, resp, util, ups, downs, osc,
     ready) = outs

    m = MinuteOut(
        served=jnp.sum(served), violated=jnp.sum(violated),
        cold_starts=jnp.sum(cold), replica_seconds=jnp.sum(total_reps),
        queue_end=state.queue, resp_sum=jnp.sum(resp * served),
        resp_max=jnp.max(resp), ups=jnp.sum(ups), downs=jnp.sum(downs),
        oscillations=jnp.sum(osc), util_mean=jnp.mean(util),
        ready_mean=jnp.mean(ready))

    # minute boundary: push this minute's arrivals into history, run hook
    hist = jnp.concatenate(
        [state.rate_history[1:], rate_this_min[None]])
    ctrl_state = controller.on_minute(state.ctrl_state, hist,
                                      minute_idx + 1)
    state = state._replace(rate_history=hist, ctrl_state=ctrl_state)
    return (state, minute_idx + 1), m


def initial_state(controller: Controller,
                  cfg: SimConfig = SimConfig()) -> SimState:
    """The t=0 plant state every simulation path starts from (the scan in
    `simulate` and the fused metrics scan in `repro.evals.metrics`)."""
    return SimState(
        ready=jnp.float32(cfg.initial_replicas),
        pipeline=jnp.zeros((cfg.startup_sec,), jnp.float32),
        queue=jnp.float32(0.0),
        wait_sum=jnp.float32(0.0),
        util_ema=jnp.float32(0.5),
        lim=limiter_init(),
        rate_history=jnp.zeros((cfg.history_len,), jnp.float32),
        ctrl_state=controller.init())


#: Public minute-granularity step: carry=(SimState, minute_idx) -> per-
#: minute MinuteOut scalars. `repro.evals.metrics` scans this directly to
#: accumulate metrics in-carry without materializing [M] outputs.
minute_step = _minute


def simulate(rates_per_min: jax.Array, controller: Controller,
             cfg: SimConfig = SimConfig()) -> MinuteOut:
    """Simulate one workload. rates_per_min [M] -> MinuteOut of [M] arrays."""
    (state, _), out = jax.lax.scan(
        partial(_minute, cfg, controller),
        (initial_state(controller, cfg), jnp.int32(0)),
        rates_per_min.astype(jnp.float32))
    return out


def make_simulator(controller: Controller, cfg: SimConfig = SimConfig()):
    """jit(vmap(simulate)): rates [W, M] -> MinuteOut of [W, M] arrays."""
    fn = jax.vmap(lambda r: simulate(r, controller, cfg))
    return jax.jit(fn)
