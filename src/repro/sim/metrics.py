"""Aggregation of simulator outputs into the paper's evaluation metrics
(§IV.D): performance (SLO violation rate, cold starts, P95/P99 response),
efficiency (replica-minutes, avg CPU utilization, over-provisioning rate),
stability (oscillations, mean interval between scaling actions).

This NumPy module is the host-side *oracle*: the device-side
implementation in ``repro.evals.metrics`` (jnp, vmap-able, in-scan
histogram quantiles) is pinned bit-close to it by tests/test_evals.py.
Pipelines that evaluate many cells should go through ``repro.evals``;
this stays the ground truth for a single MinuteOut.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.cluster import MinuteOut


@dataclasses.dataclass(frozen=True)
class EpisodeMetrics:
    # performance
    slo_violation_rate: float
    cold_start_rate: float
    mean_response_ms: float
    p95_response_ms: float
    p99_response_ms: float
    # efficiency
    replica_minutes: float
    avg_cpu_util: float
    overprovision_rate: float   # fraction of time with util < 50%
    # stability
    scaling_actions: float
    oscillations: float
    mean_action_interval_min: float
    total_requests: float

    def as_dict(self):
        return dataclasses.asdict(self)


def _weighted_quantile(values: np.ndarray, weights: np.ndarray,
                       q: float) -> float:
    """Inverted-CDF weighted quantile: the smallest value whose cumulative
    weight reaches q * total. With unit weights this equals
    ``np.percentile(values, 100 * q, method="inverted_cdf")`` (pinned by
    tests/test_evals.py). Degenerate inputs (empty, non-finite or
    non-positive total weight) return 0.0; q is clipped to [0, 1]; and the
    target is kept strictly positive so zero-weight values at either end
    of the sort order are never selected."""
    values = np.asarray(values, np.float64).reshape(-1)
    weights = np.asarray(weights, np.float64).reshape(-1)
    if values.size == 0:
        return 0.0
    total = weights.sum()
    if not np.isfinite(total) or total <= 0:
        return 0.0
    q = float(np.clip(q, 0.0, 1.0))
    order = np.argsort(values, kind="stable")
    v, w = values[order], weights[order]
    cw = np.cumsum(w)
    target = min(max(q * total, np.finfo(np.float64).tiny), total)
    idx = int(np.searchsorted(cw, target, side="left"))
    return float(v[min(idx, len(v) - 1)])


def aggregate(out: MinuteOut, workload_axis: bool = False) -> EpisodeMetrics:
    """Aggregate a MinuteOut of [M] arrays (or [W, M] with
    workload_axis=True, pooled across workloads) into EpisodeMetrics."""
    o = {k: np.asarray(v, np.float64).reshape(-1)
         for k, v in out._asdict().items()}
    served = o["served"]
    total = served.sum()
    arrived = max(total, 1.0)

    resp_mean_min = np.where(served > 0, o["resp_sum"] / np.maximum(served, 1e-9), 0.0)
    minutes = len(served)
    actions = o["ups"].sum() + o["downs"].sum()

    return EpisodeMetrics(
        slo_violation_rate=float(o["violated"].sum() / arrived),
        cold_start_rate=float(o["cold_starts"].sum() / arrived),
        mean_response_ms=float(
            1e3 * o["resp_sum"].sum() / arrived),
        p95_response_ms=1e3 * _weighted_quantile(resp_mean_min, served, 0.95),
        p99_response_ms=1e3 * _weighted_quantile(resp_mean_min, served, 0.99),
        replica_minutes=float(o["replica_seconds"].sum() / 60.0),
        avg_cpu_util=float(o["util_mean"].mean()),
        overprovision_rate=float((o["util_mean"] < 0.5).mean()),
        scaling_actions=float(actions),
        oscillations=float(o["oscillations"].sum()),
        mean_action_interval_min=float(minutes / max(actions, 1.0)),
        total_requests=float(total),
    )


def per_workload(out: MinuteOut) -> list[EpisodeMetrics]:
    """out of [W, M] arrays -> one EpisodeMetrics per workload."""
    W = np.asarray(out.served).shape[0]
    res = []
    for w in range(W):
        res.append(aggregate(
            MinuteOut(*[np.asarray(v)[w] for v in out])))
    return res
