"""Render §Eval-cards / §Obs-cards / §Tuning-cards / §Bench-trajectory /
§Dry-run-summary / §Roofline-summary markdown tables from the experiment
JSONs, the content-addressed `repro.evals` / `repro.obs` /
`repro.tuning` result cards, and the committed BENCH_*.json perf
trajectories, and append them to EXPERIMENTS.md (replacing everything
after the AUTOGEN marker)."""
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent
MARKER = "<!-- AUTOGEN SECTIONS BELOW: eval-cards / tuning-cards / dryrun-summary / roofline-summary -->"


def load(p):
    p = ROOT / p
    return json.loads(p.read_text()) if p.exists() else {}


def evals_tables():
    """One section per `repro.evals` result card under experiments/evals:
    the pre-rendered paper tables (Table IV-style policy comparison,
    Fig 2-style per-scenario breakdown, §V.D REI sensitivity), each
    addressed by its content hash."""
    root = ROOT / "experiments/evals"
    cards = sorted(root.glob("*/card.json")) if root.exists() else []
    lines = ["\n## §Eval-cards (content-addressed `repro.evals` runs)\n"]
    if not cards:
        lines.append("(no result cards yet — run `benchmarks/run.py` or "
                     "`repro.evals.matrix.run`)")
        return "\n".join(lines)
    for path in cards:
        card = json.loads(path.read_text())
        name = path.parent.name
        tables = card.get("tables")
        if tables:
            lines.append(f"\n### {name}\n")
            for title, table in tables.items():
                lines.append(f"\n**{title}**\n\n{table}\n")
        else:   # schema-light save_card payloads: one summary line
            payload = card.get("payload", {})
            keys = ", ".join(f"{k}={v}" for k, v in sorted(payload.items())
                             if isinstance(v, (int, float, str)))
            lines.append(f"\n### {name}\n\n{keys or '(payload in card)'}\n")
    return "\n".join(lines)


def obs_tables():
    """One section per `repro.obs` capture card under experiments/obs:
    the blame table (per-cause SLO-violation attribution per traced
    lane), the per-archetype split, and a pointer to the decision
    timeline, each addressed by its content hash."""
    root = ROOT / "experiments/obs"
    cards = sorted(root.glob("*/card.json")) if root.exists() else []
    lines = ["\n## §Obs-cards (content-addressed `repro.obs` captures)\n"]
    if not cards:
        lines.append("(no obs cards yet — run "
                     "`repro.obs.artifacts.capture_matrix`)")
        return "\n".join(lines)
    for path in cards:
        card = json.loads(path.read_text())
        name = path.parent.name
        totals = card.get("blame_totals", {})
        top = ", ".join(f"{k}={v:.0f}" for k, v in
                        sorted(totals.items(), key=lambda kv: -kv[1])
                        if v > 0) or "no violations"
        lines.append(f"\n### {name}\n\nblame totals: {top}; worst lane "
                     f"`{card.get('worst_lane')}` (timeline: "
                     f"`{path.parent.relative_to(ROOT)}/timeline.md`)\n")
        for title, table in card.get("tables", {}).items():
            lines.append(f"\n**{title}**\n\n{table}\n")
    return "\n".join(lines)


def bench_trajectory():
    """One table per committed BENCH_*.json: the measured perf
    trajectory each optimization PR pinned (µs/call per bench record)."""
    benches = sorted(ROOT.glob("BENCH_*.json"))
    lines = ["\n## §Bench-trajectory (committed BENCH_*.json)\n"]
    if not benches:
        lines.append("(no committed bench trajectories)")
        return "\n".join(lines)
    for path in benches:
        b = json.loads(path.read_text())
        recs = b.get("records", [])
        lines += [f"\n### {path.name} (`{b.get('bench', '?')}`, "
                  f"{b.get('elapsed_s', 0):.0f}s"
                  + (", smoke" if b.get("smoke") else "") + ")\n",
                  "| record | µs/call | derived |", "|---|---|---|"]
        for r in recs:
            d = r.get("derived") or {}
            derived = (d if isinstance(d, str) else ", ".join(
                f"{k}={v:.3g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in sorted(d.items())))
            us = r.get("us_per_call")
            us = "-" if us is None else f"{us:,.1f}"
            lines.append(f"| {r['name']} | {us} | {derived or '-'} |")
    return "\n".join(lines)


def dryrun_table():
    r = load("experiments/dryrun/results.json")
    lines = [
        "\n## §Dry-run-summary (final sweep)\n",
        f"{sum(1 for v in r.values() if v.get('ok'))}/{len(r)} cells "
        "compiled (32 live cells x single-pod 16x16 + multi-pod 2x16x16).\n",
        "Per-device memory (argument + temp bytes from "
        "`compiled.memory_analysis()`; decode outputs alias donated "
        "caches), single-pod mesh:\n",
        "| arch | shape | compile s | args GB | temp GB | total GB | coll GB (scanned artifact) |",
        "|---|---|---|---|---|---|---|",
    ]
    for k in sorted(r):
        v = r[k]
        if not v.get("ok") or k.endswith("|multi"):
            continue
        m = v["memory"]
        a = m["argument_bytes"] / 1e9
        t = m["temp_bytes"] / 1e9
        tot = a + t
        flag = " **(over)**" if tot > 16 else ""
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['compile_s']:.0f} "
            f"| {a:.2f} | {t:.2f} | {tot:.2f}{flag} "
            f"| {v['collectives']['total_bytes']/1e9:.2f} |")
    multi_ok = sum(1 for k, v in r.items()
                   if k.endswith("|multi") and v.get("ok"))
    lines.append(f"\nMulti-pod (2x16x16) pass: {multi_ok}/32 cells compile "
                 "— the \"pod\" axis shards (FSDP over (pod,data)); table in "
                 "results.json.")
    return "\n".join(lines)


def roofline_table():
    r = load("experiments/roofline/results.json")
    lines = [
        "\n## §Roofline-summary (single-pod, unrolled probes)\n",
        "Terms in seconds/step-equivalent per §Roofline methodology. "
        "`useful` = MODEL_FLOPS / HLO_FLOPs (NB: excludes attention "
        "FLOPs by convention, so long-KV decode is legitimately small); "
        "`frac` = compute_s / max(terms). The memory term uses XLA "
        "`bytes accessed` (pre-fusion operand bytes) — an upper bound on "
        "HBM traffic; on-chip fusion lowers real traffic, so `frac` here "
        "is conservative.\n",
        "| arch | shape | compute_s | memory_s | coll_s | dominant | useful | frac | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    LEVERS = {
        "train": "fuse/stream optimizer + larger per-device batch to raise arithmetic intensity",
        "prefill": "wider q/kv tiles + fp8 KV writes to cut cache-write bytes",
        "decode": "fp8 KV cache halves cache reads; batch more sequences per chip",
    }
    for k in sorted(r):
        v = r[k]
        if "error" in v:
            lines.append(f"| {k.split('|')[0]} | {k.split('|')[1]} | - | - | - | ERROR | - | - | {v['error'][:40]} |")
            continue
        kind = ("decode" if "decode" in v["shape"] or "long" in v["shape"]
                else ("prefill" if "prefill" in v["shape"] else "train"))
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['compute_s']:.2e} "
            f"| {v['memory_s']:.2e} | {v['collective_s']:.2e} "
            f"| {v['dominant']} | {v['useful_flop_ratio']:.2f} "
            f"| {v['roofline_fraction']:.3f} | {LEVERS[kind]} |")
    return "\n".join(lines)


def tuning_tables():
    """One row per `repro.tuning` card under experiments/tuning: the
    search winner vs the paper default, addressed by content hash (the
    same hash `registry.make("tuned:<policy>@<hash>")` resolves)."""
    root = ROOT / "experiments/tuning"
    cards = sorted(root.glob("*/card.json")) if root.exists() else []
    lines = ["\n## §Tuning-cards (content-addressed `repro.tuning` runs)\n"]
    if not cards:
        lines.append("(no tuning cards yet — run "
                     "`repro.tuning.search.search` or "
                     "`benchmarks/run.py tuning`)")
        return "\n".join(lines)
    lines += ["| card | policy | strategy | candidates | default REI "
              "| tuned REI | delta | best point |",
              "|---|---|---|---|---|---|---|---|"]
    for path in cards:
        card = json.loads(path.read_text())
        best = ", ".join(f"{k}={v:.3g}" if isinstance(v, float)
                         else f"{k}={v}"
                         for k, v in sorted(card["best"].items()))
        lines.append(
            f"| {path.parent.name} | {card['policy']} "
            f"| {card['spec']['strategy']} "
            f"| {card['meta']['n_candidates']} "
            f"| {card['default_rei']:.3f} | {card['best_rei']:.3f} "
            f"| {card['rei_delta']:+.3f} | {best} |")
    return "\n".join(lines)


def main():
    p = ROOT / "EXPERIMENTS.md"
    text = p.read_text() if p.exists() else f"# Experiments\n\n{MARKER}\n"
    head = text.split(MARKER)[0] + MARKER + "\n"
    p.write_text(head + evals_tables() + "\n" + obs_tables() + "\n"
                 + tuning_tables() + "\n" + bench_trajectory() + "\n"
                 + dryrun_table() + "\n" + roofline_table() + "\n")
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
