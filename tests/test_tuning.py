"""The `repro.tuning` search plane: fused grid evaluator parity with the
per-candidate controller loop, one-compile-per-static-group pins,
content-addressed tuning cards (determinism + cache hits), the `tuned:`
registry namespace, and a pinned scenario where grid+refine beats the
paper defaults. Full-size searches carry the `slow` marker."""
import numpy as np
import jax.numpy as jnp
import pytest

import repro.tuning as tuning
from repro.tuning import artifacts
from repro.evals import matrix as EX
from repro.evals import metrics as EM
from repro.evals import rei as ER
from repro.scaling import batch, registry
from repro.sim.cluster import SimConfig, simulate

CFG = SimConfig()


def _rates(shape, lam=2400, seed=0):
    return np.random.default_rng(seed).poisson(
        lam, shape).astype(np.float32)


# ---------------------------------------------------- fused evaluation ----
def test_grid_evaluator_matches_controller_loop():
    """Pooled EpisodeMetrics + REI per fused candidate lane equal the
    `get_controller`-per-candidate evaluation of the same points."""
    grid = [{"target": 0.5, "cooldown_min": 2.0},
            {"target": 0.7, "cooldown_min": 5.0},
            {"target": 0.9, "cooldown_min": 8.0}]
    rates = _rates((2, 120), seed=1)
    met, rb = batch.make_grid_evaluator("hpa", CFG)(grid, rates)
    ctrls = [registry.get_controller("hpa", CFG, **g) for g in grid]
    pooled, _ = EX.evaluate_controllers(ctrls, jnp.asarray(rates), CFG,
                                        per_workload=False)
    for f in EM.EpisodeMetrics._fields:
        np.testing.assert_allclose(np.asarray(getattr(met, f)),
                                   np.asarray(getattr(pooled, f)),
                                   rtol=2e-5, atol=1e-5, err_msg=f)
    ref_rei = ER.rei(pooled.slo_violation_rate, pooled.replica_minutes,
                     pooled.scaling_actions, minutes=120, n_workloads=2)
    np.testing.assert_allclose(np.asarray(rb.rei),
                               np.asarray(ref_rei.rei),
                               rtol=2e-5, atol=1e-5)


def test_grid_evaluator_one_compile_per_static_group():
    """Traced points share one compile; each distinct static value adds
    exactly one more; re-evaluating with new traced values adds none."""
    rates = _rates((2, 60), seed=2)
    ev = batch.make_grid_evaluator("hpa", CFG)
    ev([{"target": t} for t in (0.5, 0.7, 0.9)], rates)
    assert ev._cache_size() == 1
    ev([{"target": t} for t in (0.45, 0.85, 0.65)], rates)
    assert ev._cache_size() == 1         # same shapes, no retrace
    ev([{"target": 0.6, "stabilization_min": s} for s in (2.0, 8.0)],
       rates)
    assert ev._cache_size() == 3         # two new static groups of G=1


def test_search_space_validation():
    with pytest.raises(TypeError, match=r"targett.*accepts"):
        tuning.spec("x", policy="hpa", space={"targett": (0.4, 0.9)})
    with pytest.raises(TypeError, match="not stackable"):
        tuning.spec("x", policy="hpa",
                    space={"stabilization_min": ("range", 1.0, 9.0)})
    with pytest.raises(ValueError, match="empty range"):
        tuning.spec("x", policy="hpa", space={"target": (0.9, 0.4)})
    with pytest.raises(ValueError, match="unknown strategy"):
        tuning.spec("x", policy="hpa", strategy="simulated_annealing")


# ------------------------------------------------- artifacts + caching ----
def _tiny_spec(name="tiny", **kw):
    base = dict(policy="hpa", strategy="grid", points=3,
                space={"target": (0.45, 0.9)},
                n_workloads=2, minutes=60)
    base.update(kw)
    return tuning.spec(name, **base)


def test_artifact_determinism_and_cache_hit(tmp_path, monkeypatch):
    sp = _tiny_spec(name="det")
    run1 = tuning.search(sp, root=tmp_path)
    assert not run1.cached
    # identical spec -> identical address, and the cached card is served
    # without re-running the search
    calls = []
    real = tuning.run_search
    import sys
    search_mod = sys.modules["repro.tuning.search"]
    monkeypatch.setattr(search_mod, "run_search",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    run2 = tuning.search(sp, root=tmp_path)
    assert run2.cached and not calls
    assert run2.card["hash"] == run1.card["hash"]
    assert run2.result.best == run1.result.best
    assert run2.result.best_rei == pytest.approx(run1.result.best_rei)
    # different seed -> different address
    assert artifacts.card_hash(
        _tiny_spec(name="det", seed=1).content_key() | {"classifier": ""}
    ) != artifacts.card_hash(sp.content_key() | {"classifier": ""})
    # force=True re-executes and republishes at the same address
    run3 = tuning.search(sp, root=tmp_path, force=True)
    assert not run3.cached and calls
    assert run3.card["hash"] == run1.card["hash"]


def test_tuned_registry_round_trip(tmp_path, monkeypatch):
    """`registry.make("tuned:<policy>@<hash>")` rebuilds the winning
    controller bit-exactly from the content-addressed card."""
    sp = _tiny_spec(name="roundtrip")
    run = tuning.search(sp, root=tmp_path)
    monkeypatch.setattr(artifacts, "DEFAULT_ROOT", tmp_path)
    ref = f"tuned:hpa@{run.card['hash']}"
    tuned = registry.make(ref, CFG)
    direct = registry.make("hpa", CFG, **run.result.best)
    assert registry.spec(ref).name == "hpa"
    rates = jnp.asarray(_rates(90, seed=3))
    out_t, out_d = simulate(rates, tuned, CFG), simulate(rates, direct, CFG)
    for f in out_t._fields:
        assert bool(jnp.array_equal(getattr(out_t, f),
                                    getattr(out_d, f))), f
    # overrides still apply on top of the tuned point
    hot = registry.make(ref, CFG, cooldown_min=0.0)
    assert hot.name == tuned.name
    # wrong-policy refs and unknown hashes fail loudly
    with pytest.raises(ValueError, match="tuned"):
        registry.make(f"tuned:kpa@{run.card['hash']}", CFG)
    with pytest.raises(FileNotFoundError):
        registry.make("tuned:hpa@000000000000", CFG)


def test_population_search_is_deterministic():
    sp = tuning.spec("pop", policy="kpa", strategy="population",
                     population=6, generations=2, n_workloads=2,
                     minutes=60)
    r1, r2 = tuning.run_search(sp), tuning.run_search(sp)
    assert r1.best == r2.best
    assert r1.best_rei == pytest.approx(r2.best_rei)
    assert [t["best_rei"] for t in r1.trace] == \
        pytest.approx([t["best_rei"] for t in r2.trace])


# ------------------------------------------------ tuned beats defaults ----
def test_grid_refine_beats_paper_defaults_on_drift():
    """Pinned scenario: on diurnal_ramp (the drift case) a small
    grid+refine over the hpa box strictly improves REI over the paper
    defaults — the experiment the tuning plane exists to run."""
    sp = tuning.spec("drift_refine", policy="hpa", strategy="grid_refine",
                     scenario="diurnal_ramp", points=3, rounds=2,
                     n_workloads=2, minutes=120)
    r = tuning.run_search(sp)
    assert r.best_rei > r.default_rei + 0.01
    assert len(r.trace) == 2
    assert r.meta["n_candidates"] == sum(t["n_candidates"]
                                         for t in r.trace)
    # refine round 2 searches a shrunk box around the round-1 incumbent
    b0, b1 = (t["box"]["target"] for t in r.trace)
    assert (b1[1] - b1[0]) == pytest.approx(
        (b0[1] - b0[0]) * sp.shrink, rel=1e-6)


@pytest.mark.slow
def test_full_searches_converge():
    """Nightly: full-size grid+refine and population searches on the
    SPIKE scenario find at-least-as-good points as the quick versions
    and converge (final-round incumbent == overall best)."""
    for strategy in ("grid_refine", "population"):
        sp = tuning.spec(f"full_{strategy}", policy="hpa",
                         strategy=strategy, scenario="archetype_pure",
                         points=5, rounds=4, population=32, generations=6,
                         n_workloads=4, minutes=240)
        r = tuning.run_search(sp)
        assert r.best_rei >= r.default_rei
        assert r.trace[-1]["best_rei"] == pytest.approx(r.best_rei)
