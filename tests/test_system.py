"""End-to-end behaviour: traces -> weak labels -> classifier -> calibrated
confidence -> archetype-aware autoscaling, on a miniature dataset."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import gbdt, pipeline
from repro.core.controllers import aapa_controller, hpa_controller
from repro.data import windows as W
from repro.data.azure_synth import generate_traces
from repro.sim import metrics as MM
from repro.sim.cluster import SimConfig, make_simulator



# Heavyweight model/train/system tier: nightly CI runs these; tier-1 deselects
# with -m 'not slow'.
pytestmark = pytest.mark.slow

@pytest.fixture(scope="module")
def mini():
    traces = generate_traces(n_functions=24, n_days=4, seed=7)
    trained = pipeline.train_aapa(
        traces, gbdt.GBDTConfig(n_rounds=15, depth=3))
    return traces, trained


def test_windows_and_splits():
    traces = generate_traces(n_functions=6, n_days=14, seed=0)
    ds = W.make_windows(traces)
    assert ds.windows.shape[1] == 60
    split = W.day_split(ds)
    n = sum(m.sum() for m in split.values())
    assert n == len(ds)  # partitions cover everything
    assert split["train"].sum() > split["val"].sum()
    # no window leaks across split days
    d = ds.day()
    assert d[split["train"]].max() <= 9
    assert d[split["test"]].min() >= 12


def test_classifier_accuracy_on_weak_labels(mini):
    _, trained = mini
    # paper: 99.8% — mini dataset should still be >97%
    assert trained.test_acc > 0.97
    assert trained.n_windows > 1000
    assert abs(trained.label_dist.sum() - 1.0) < 1e-6


def test_aapa_beats_hpa_on_violations(mini):
    traces, trained = mini
    cfg = SimConfig()
    classify = trained.make_classify()
    rates = jnp.asarray(traces.counts[:12, :1440])

    hpa_out = make_simulator(hpa_controller(cfg), cfg)(rates)
    aapa_out = make_simulator(aapa_controller(cfg, classify), cfg)(rates)
    hpa_m = MM.aggregate(hpa_out, workload_axis=True)
    aapa_m = MM.aggregate(aapa_out, workload_axis=True)

    # the paper's central claims, directionally: fewer violations and
    # fewer cold starts, at higher resource cost
    assert aapa_m.slo_violation_rate <= hpa_m.slo_violation_rate
    assert aapa_m.cold_start_rate <= hpa_m.cold_start_rate
    assert aapa_m.replica_minutes > hpa_m.replica_minutes


def test_classify_closure_jits(mini):
    _, trained = mini
    classify = trained.make_classify()
    feats = jnp.zeros((38,), jnp.float32)
    arch, conf = jax.jit(classify)(feats)
    assert arch.shape == () and 0.0 <= float(conf) <= 1.0
