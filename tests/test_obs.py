"""Decision-telemetry contracts: the off path is free, the on path is
faithful, and blame is conservative.

* telemetry=False compiles to the pre-PR program — MinuteOut bit-exact
  against the telemetry run for every registry policy (single lane and
  the fused batch), so capture can never perturb scores.
* telemetry=True keeps ONE compile on the matrix runner and produces
  traces whose decisions replay the head schedule exactly.
* blame attribution is conservative by construction: per-cause violation
  counts sum to the pooled EpisodeMetrics violation total.
* the engine adapter logs the same DecisionRecord schema the sim scan
  captures — the two streams agree on a shared trace (sim-vs-engine
  telemetry parity).
"""
import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.evals import fleet, matrix
from repro.obs import artifacts as OA
from repro.obs import attribute as AT
from repro.obs import trace as T
from repro.scaling import adapter, batch, registry, scenarios
from repro.sim.cluster import SimConfig, simulate

REPO = pathlib.Path(__file__).resolve().parent.parent


def _rates(minutes=90, seed=3):
    cfg = SimConfig()
    sc = scenarios.get("burst_storm", n_workloads=2, minutes=minutes,
                       seed=seed, cfg=cfg)
    return np.asarray(sc.rates, np.float32)


def _ctrl(policy, cfg, **kw):
    if registry.spec(policy).takes_forecaster:
        kw.setdefault("forecaster", "holt_winters")
    return registry.get_controller(policy, cfg, **kw)


# ------------------------------------------------- off-path bit-exactness
@pytest.mark.parametrize("policy", registry.available())
def test_telemetry_off_is_bit_exact_per_policy(policy):
    """The telemetry=False default and the telemetry=True capture run
    the same control path: MinuteOut identical bit for bit."""
    cfg = SimConfig()
    rates = jnp.asarray(_rates()[0])
    ctrl = _ctrl(policy, cfg)
    base = simulate(rates, ctrl, cfg)
    out, ct = simulate(rates, ctrl, cfg, telemetry=True)
    for f, a, b in zip(base._fields, base, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    M = rates.shape[0]
    H = len(T.head_schedule(cfg))
    assert np.asarray(ct.decisions.desired).shape == (M, H)
    assert np.asarray(ct.minutes.rate).shape == (M,)


def test_batch_telemetry_bit_exact_and_lane_sampled():
    """Fused batch path: telemetry (full and lane-sampled) leaves the
    MinuteOut stream bit-exact, and the sampled trace is a slice of the
    full one."""
    cfg = SimConfig()
    rates = _rates()
    ctrls = [_ctrl(p, cfg) for p in ("hpa", "predictive", "aapa")]
    sim0 = batch.make_batch_simulator(ctrls, cfg)
    sim1 = batch.make_batch_simulator(ctrls, cfg, telemetry=True)
    sim2 = batch.make_batch_simulator(ctrls, cfg, telemetry=True,
                                      trace_lanes=1)
    base = jax.block_until_ready(sim0(rates))
    out1, ct1 = jax.block_until_ready(sim1(rates))
    out2, ct2 = jax.block_until_ready(sim2(rates))
    for f, a, b, c in zip(base._fields, base, out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c),
                                      err_msg=f)
    idx = T.sample_lanes(rates.shape[0], 1)
    np.testing.assert_array_equal(
        np.asarray(ct2.decisions.desired),
        np.asarray(ct1.decisions.desired)[..., idx])


def test_trace_head_schedule_nondividing_interval():
    """ci=7 doesn't divide 60: the trace's sec field must replay the
    blocked scan's exact head schedule (including the tail head)."""
    cfg = SimConfig(control_interval_sec=7)
    rates = jnp.asarray(_rates(minutes=10)[0])
    _, ct = simulate(rates, _ctrl("hpa", cfg), cfg, telemetry=True)
    heads = T.head_schedule(cfg)
    assert heads == [0, 7, 14, 21, 28, 35, 42, 49, 56]
    np.testing.assert_array_equal(
        np.asarray(ct.decisions.sec)[0], np.asarray(heads, np.float32))


def test_explain_signals_per_policy():
    """hpa carries no signals (NaN), predictive carries the forecast,
    aapa adds confidence + archetype, hybrid adds the guard floor."""
    cfg = SimConfig()
    rates = jnp.asarray(_rates(minutes=30)[0])
    traces = {p: simulate(rates, _ctrl(p, cfg), cfg, telemetry=True)[1]
              for p in ("hpa", "predictive", "aapa", "hybrid")}
    d = {p: ct.decisions for p, ct in traces.items()}
    assert np.all(np.isnan(np.asarray(d["hpa"].fc_point)))
    assert np.any(np.isfinite(np.asarray(d["predictive"].fc_point)))
    assert np.all(np.isnan(np.asarray(d["predictive"].confidence)))
    assert np.any(np.isfinite(np.asarray(d["aapa"].confidence)))
    assert np.any(np.isfinite(np.asarray(d["aapa"].archetype)))
    assert np.any(np.isfinite(np.asarray(d["hybrid"].guard_floor)))


# ------------------------------------------------------- matrix + fleet
def test_matrix_runner_telemetry_one_compile_and_bit_exact():
    sp = matrix.smoke_spec()
    rates = matrix.build_rates(sp)
    pool0, perw0 = jax.block_until_ready(matrix.make_runner(sp)(rates))
    run1 = matrix.make_runner(sp, telemetry=True)
    pool1, perw1, ct = jax.block_until_ready(run1(rates))
    assert run1._cache_size() == 1
    for f, a, b in zip(pool0._fields, pool0, pool1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    S, Z, F, P = sp.shape
    H = len(T.head_schedule(sp.sim_config()))
    assert np.asarray(ct.decisions.desired).shape == (
        S, Z, sp.minutes, H, F, P, sp.n_workloads)
    assert np.asarray(ct.minutes.violated).shape == (
        S, Z, sp.minutes, F, P, sp.n_workloads)


def test_fleet_trace_lanes_rides_chunk_scan():
    sp0 = fleet.spec("obs_t", policies=("hpa", "predictive"),
                     n_workloads=8, w_chunk=4, minutes=20, seed=1)
    sp1 = fleet.spec("obs_t", policies=("hpa", "predictive"),
                     n_workloads=8, w_chunk=4, minutes=20, seed=1,
                     trace_lanes=2)
    r0, r1 = fleet.run_fleet(sp0), fleet.run_fleet(sp1)
    assert r0.trace is None
    for f, a, b in zip(r0.pooled._fields, r0.pooled, r1.pooled):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f)
    H = len(T.head_schedule(sp1.sim_config()))
    assert r1.trace.decisions.desired.shape == (2, 20, H, 2, 2)
    assert r1.trace.minutes.rate.shape == (2, 20, 2, 2)
    with pytest.raises(ValueError, match="one-dispatch"):
        fleet.run_fleet(sp1, stream=True)


# ------------------------------------------------------------ attribution
def test_blame_counts_sum_to_pooled_violations():
    """The acceptance pin: per-cause blame totals over every traced lane
    sum to the pooled EpisodeMetrics violation total (violation_rate x
    arrivals), because each violated minute lands in exactly one cause."""
    sp = matrix.smoke_spec()
    cfg = sp.sim_config()
    rates = matrix.build_rates(sp)
    pool, _, ct = jax.block_until_ready(
        matrix.make_runner(sp, telemetry=True)(rates))
    ct = T.to_numpy(ct)
    blame_total = 0.0
    K = ct.minutes.rate.shape[-1]
    for label, pre, post in OA._lane_labels(sp, K):
        b = AT.attribute(T.lane(ct, pre, post), cfg)
        assert sum(b.counts.values()) == pytest.approx(b.total)
        blame_total += sum(b.counts.values())
    arrived = float(np.asarray(ct.minutes.rate, np.float64).sum())
    pooled_violated = float(
        (np.asarray(pool.slo_violation_rate, np.float64)
         * np.asarray(ct.minutes.rate, np.float64)
            .sum(axis=(2, 5))).sum())
    assert blame_total == pytest.approx(pooled_violated, rel=1e-5)
    assert blame_total > 0 and arrived > 0


def test_blame_cascade_buckets_reachable():
    """capacity_capped and cooldown_suppressed fire on scenarios built
    to trigger them; every minute's cause indexes CAUSES."""
    cfg = SimConfig(max_replicas=3.0)
    rates = jnp.asarray(np.full(20, 20000.0, np.float32))
    _, ct = simulate(rates, _ctrl("hpa", cfg), cfg, telemetry=True)
    b = AT.attribute(T.to_numpy(ct), cfg)
    assert b.counts["capacity_capped"] > 0

    cfg2 = SimConfig()
    lull = np.concatenate([np.full(20, 6000.0), np.full(10, 100.0),
                           np.full(20, 6000.0)]).astype(np.float32)
    _, ct2 = simulate(jnp.asarray(lull), _ctrl("hpa", cfg2), cfg2,
                      telemetry=True)
    b2 = AT.attribute(T.to_numpy(ct2), cfg2)
    assert b2.counts["cooldown_suppressed"] > 0
    for b_ in (b, b2):
        assert set(np.unique(b_.cause)) <= set(range(-1, len(AT.CAUSES)))


def test_blame_tables_render():
    cfg = SimConfig()
    rates = jnp.asarray(_rates(minutes=60)[0])
    _, ct = simulate(rates, _ctrl("aapa", cfg), cfg, telemetry=True)
    ct = T.to_numpy(ct)
    b = AT.attribute(ct, cfg)
    tbl = AT.blame_table({"aapa": b})
    assert "| lane |" in tbl and "aapa" in tbl
    arch = AT.archetype_table(AT.archetype_counts(ct, b))
    assert "archetype" in arch
    tl = AT.timeline(ct, b, max_rows=24)
    # bounded: blamed minutes are always kept, the rest is subsampled
    H = np.asarray(ct.decisions.minute).shape[1]
    n_blamed = int((b.cause >= 0).sum())
    assert tl.count("\n") <= 2 + H * (n_blamed + max(24 // H, 1))
    assert tl.count("\n") < AT.timeline(ct, b, max_rows=10**6).count("\n")
    for m in np.nonzero(b.cause >= 0)[0]:        # blamed minutes kept
        assert f"| {m}m00s |" in tl


# -------------------------------------------------------------- obs cards
def test_obs_card_publish_and_cache(tmp_path):
    sp = matrix.smoke_spec()
    cap = OA.capture_matrix(sp, root=tmp_path)
    assert not cap.cached
    out = OA.capture_dir(sp.name, cap.card["key"], tmp_path)
    assert (out / "card.json").exists()
    assert (out / "trace.npz").exists()
    assert (out / "timeline.md").exists()
    assert cap.card["violations_total"] == pytest.approx(
        sum(cap.card["blame_totals"].values()))
    cap2 = OA.capture_matrix(sp, root=tmp_path)
    assert cap2.cached
    np.testing.assert_array_equal(
        np.asarray(cap.trace.decisions.desired),
        np.asarray(cap2.trace.decisions.desired))
    assert list(cap.blames) == list(cap2.blames)
    with open(out / "card.json") as f:
        card = json.load(f)
    assert card["tables"]["blame"].startswith("| lane |")


# ------------------------------------------------- sim-vs-engine parity
class FakeEngine:
    """Minimal duck-typed engine (mirrors test_scaling.FakeEngine)."""

    def __init__(self, *, ready=2, lanes=20, startup_s=30.0, slo_s=0.5,
                 max_replicas=100):
        self.ready_replicas = ready
        self.lanes = lanes
        self.startup_s = startup_s
        self.slo_s = slo_s
        self.max_replicas = max_replicas
        self.starting, self.active, self.queue = [], [], []
        self.t = 0.0
        self.arrivals_total = 0.0
        self.rate = 0.0

    def observed_rate(self, window_s):
        return self.rate

    def scale_to(self, n):
        self.ready_replicas = n


def test_sim_vs_engine_decision_records_agree():
    """The same rate trace through the compiled sim scan and the eager
    engine adapter yields DecisionRecord streams that agree on the
    predictive policy's desired/cooldown/forecast fields (its decide
    reads only rate history + forecast, which both plants feed
    identically)."""
    cfg = SimConfig()
    minutes = 40
    rng = np.random.default_rng(5)
    rates = np.round(rng.gamma(2.0, 400.0, minutes)).astype(np.float32)

    ctrl = _ctrl("predictive", cfg)
    _, ct = simulate(jnp.asarray(rates), ctrl, cfg, telemetry=True)
    sim_d = T.to_numpy(ct).decisions                      # [M, H]

    eng = FakeEngine(ready=int(cfg.initial_replicas))
    auto = adapter.EngineAutoscaler(eng, _ctrl("predictive", cfg), cfg,
                                    minute_s=60.0)
    heads = T.head_schedule(cfg)
    for m in range(minutes):
        eng.rate = float(rates[m]) / 60.0
        for sec in heads:
            eng.t = m * 60.0 + sec
            auto.on_tick()
        eng.arrivals_total += float(rates[m])
        eng.t = (m + 1) * 60.0 - 1e-9
    eng_d = auto.decision_trace()                         # [N]

    H = len(heads)
    n = min(minutes * H, len(eng_d.desired))
    for field in ("desired_raw", "desired", "cooldown_req", "fc_point"):
        a = np.asarray(getattr(sim_d, field)).reshape(-1)[:n]
        b = np.asarray(getattr(eng_d, field))[:n]
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                   equal_nan=True, err_msg=field)
    np.testing.assert_array_equal(
        np.asarray(sim_d.minute).reshape(-1)[:n], eng_d.minute[:n])
    np.testing.assert_array_equal(
        np.asarray(sim_d.sec).reshape(-1)[:n], eng_d.sec[:n])


def test_run_autoscaled_returns_decision_trace():
    eng = FakeEngine()
    ctrl = _ctrl("hpa", SimConfig())
    summary_calls = {}

    class SummaryEngine(FakeEngine):
        def step(self):
            self.t += 15.0

        def summary(self):
            summary_calls["hit"] = True
            return {"served": 0}

    eng = SummaryEngine()
    summary, trace = adapter.run_autoscaled(
        eng, ctrl, submit_fn=lambda i, e: None, n_steps=8,
        cfg=SimConfig(), minute_s=60.0)
    assert summary_calls["hit"] and summary == {"served": 0}
    assert isinstance(trace, T.DecisionRecord)
    assert len(trace.desired) > 0
    assert np.all(np.isnan(trace.fc_point))     # hpa has no forecast


# ------------------------------------------------------- profile smoke
def test_bench_profile_writes_trace_dir(tmp_path):
    """benchmarks.run --profile captures a non-empty jax.profiler trace
    directory per bench (what the nightly CI job uploads)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "sim", "--smoke",
         "--json", str(tmp_path), "--profile", str(tmp_path / "prof")],
        check=True, cwd=REPO, timeout=3000, env=env)
    traced = list((tmp_path / "prof" / "sim").rglob("*"))
    assert any(p.is_file() for p in traced)
    assert (tmp_path / "BENCH_sim.json").exists()
