"""SSD (Mamba2) chunked-vs-recurrent equivalence + MoE routing."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.common import ModelConfig
from repro.models.moe import init_moe, moe_block_scatter, moe_capacity
from repro.models.ssm import (init_mamba2, init_ssm_cache, mamba2_block,
                              ssd_chunked, ssd_decode_step)

# Heavyweight model/train/system tier: nightly CI runs these; tier-1 deselects
# with -m 'not slow'.
pytestmark = pytest.mark.slow


def _ssd_inputs(seed=0, B=2, L=32, H=3, P=5, N=7):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.5, (B, L, H)), jnp.float32),
            jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32))


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_recurrence(chunk):
    x, dt, A, Bm, Cm = _ssd_inputs()
    state = jnp.zeros((2, 3, 7, 5))
    ys = []
    for t in range(32):
        state, y = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                   Bm[:, t], Cm[:, t])
        ys.append(y)
    ref = jnp.stack(ys, axis=1)
    got, fs = ssd_chunked(x, dt, A, Bm, Cm, chunk, return_state=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state),
                               rtol=1e-4, atol=1e-4)


def test_mamba_block_prefill_equals_stepwise():
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=16,
                      n_heads=0, d_ff=0, vocab=8, d_state=8, ssm_head_dim=8,
                      ssm_chunk=8, dtype="float32")
    p = init_mamba2(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 16)),
                    jnp.float32)
    out_pf, cache_pf = mamba2_block(p, x, cfg,
                                    cache=init_ssm_cache(cfg, 2), pos=0)
    cache = init_ssm_cache(cfg, 2)
    outs = []
    for t in range(16):
        o, cache = mamba2_block(p, x[:, t:t + 1], cfg, cache=cache, pos=t)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_pf),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache_pf["ssm"]),
                               np.asarray(cache["ssm"]), rtol=1e-4,
                               atol=1e-4)


def _moe_cfg(**kw):
    base = dict(name="t", family="moe_gqa", n_layers=1, d_model=16,
                n_heads=4, d_ff=32, vocab=8, n_experts=4, top_k=2,
                d_ff_expert=32, capacity_factor=8.0, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_matches_per_token_dense_reference():
    """With huge capacity (no drops), scatter MoE == explicit per-token
    top-k mixture."""
    cfg = _moe_cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, 16)),
                    jnp.float32)
    out, aux = moe_block_scatter(p, x, cfg)

    xf = x.reshape(-1, 16)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(xf))
    for e in range(4):
        h = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        ye = np.asarray(h @ p["w_down"][e])
        for j in range(2):
            m = np.asarray(idx[:, j] == e)
            ref[m] += np.asarray(gate[:, j])[m, None] * ye[m]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)), ref,
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_overflow():
    cfg = _moe_cfg(capacity_factor=0.01)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((4, 16, 16), jnp.float32)
    out, _ = moe_block_scatter(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(out)))
    cap = moe_capacity(cfg, 64)
    assert cap >= 8  # floor


def test_moe_capacity_formula():
    cfg = _moe_cfg(capacity_factor=1.25)
    assert moe_capacity(cfg, 1024) == int(np.ceil(1024 * 2 / 4 * 1.25))
