"""Cluster simulator invariants + controller behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypo_compat import given, settings, st

from repro.core.controllers import (aapa_controller, hpa_controller,
                                    predictive_controller)
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig, make_simulator, simulate

CFG = SimConfig()


def _run(rates, ctrl=None, cfg=CFG):
    ctrl = ctrl or hpa_controller(cfg)
    out = simulate(jnp.asarray(rates, jnp.float32), ctrl, cfg)
    return jax.tree.map(np.asarray, out)


def test_conservation_served_never_exceeds_arrivals():
    rng = np.random.default_rng(0)
    rates = rng.poisson(600, 180).astype(np.float32)  # 3 busy hours
    out = _run(rates)
    total_arrived = rates.sum()
    served = out.served.sum()
    # f32 slack: at ~1e5 total requests one ulp is ~8e-3, so an absolute
    # 1e-3 bound is below the rounding of the served accumulation itself
    assert served <= total_arrived * (1 + 1e-6) + 1e-3
    # whatever wasn't served must still be queued
    assert served + out.queue_end[-1] == pytest.approx(total_arrived,
                                                       rel=1e-5)


def test_replica_bounds_respected():
    rates = np.full(120, 1e9, np.float32)  # absurd overload
    out = _run(rates)
    assert out.ready_mean.max() <= CFG.max_replicas + 1e-2  # float accum


def test_idle_trace_scales_to_zero_and_cold_starts():
    rates = np.zeros(240, np.float32)
    rates[200] = 60.0  # burst after a long idle stretch
    out = _run(rates)
    assert out.ready_mean[150] == pytest.approx(0.0, abs=1e-6)  # idle->0
    assert out.cold_starts.sum() > 0                    # burst cold-starts
    assert out.served.sum() == pytest.approx(60.0, rel=1e-3)  # eventually
    assert out.violated[200:].sum() > 0                 # and they violated


def test_hpa_scales_up_under_load():
    rates = np.concatenate([np.full(30, 600.0),
                            np.full(90, 18000.0)]).astype(np.float32)
    out = _run(rates)
    # 18000/min = 300 rps needs 15 replicas at 100% (more at 70% target)
    assert out.ready_mean[-1] > 14


def test_aapa_spike_policy_keeps_warm_pool():
    cfg = CFG

    def classify(feats):
        return jnp.int32(1), jnp.float32(1.0)  # SPIKE, certain

    rates = np.full(120, 1.0, np.float32)      # nearly idle
    out = _run(rates, aapa_controller(cfg, classify))
    # Table III: SPIKE min replicas 2 + warm pool 2 -> never below ~4
    assert out.ready_mean[60:].min() >= 3.0
    assert out.cold_starts.sum() == 0.0


def test_aapa_uncertainty_increases_replicas():
    cfg = CFG
    rates = np.full(120, 1.0, np.float32)

    def certain(feats):
        return jnp.int32(1), jnp.float32(1.0)

    def uncertain(feats):
        return jnp.int32(1), jnp.float32(0.0)

    r_cert = _run(rates, aapa_controller(cfg, certain))
    r_unc = _run(rates, aapa_controller(cfg, uncertain))
    assert r_unc.replica_seconds.sum() > r_cert.replica_seconds.sum()


def test_predictive_prescales_on_periodic():
    t = np.arange(240)
    rates = (6000 + 5500 * np.sin(2 * np.pi * t / 60.0)).astype(np.float32)
    hpa = M.aggregate(_run(rates))
    pred = M.aggregate(_run(rates, predictive_controller(CFG)))
    # predictive should violate less on a clean periodic signal
    assert pred.slo_violation_rate <= hpa.slo_violation_rate + 1e-9


def test_vmapped_simulator_matches_single():
    rng = np.random.default_rng(1)
    rates = rng.poisson(1200, size=(3, 120)).astype(np.float32)
    ctrl = hpa_controller(CFG)
    sim = make_simulator(ctrl, CFG)
    batched = jax.tree.map(np.asarray, sim(jnp.asarray(rates)))
    single = _run(rates[1], ctrl)
    np.testing.assert_allclose(batched.served[1], single.served, rtol=1e-5)
    np.testing.assert_allclose(batched.ready_mean[1], single.ready_mean,
                               rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_sim_state_never_negative(seed):
    rng = np.random.default_rng(seed)
    rates = rng.poisson(rng.uniform(1, 5000), 90).astype(np.float32)
    out = _run(rates)
    assert (out.queue_end >= -1e-5).all()
    assert (out.ready_mean >= -1e-6).all()
    assert (out.served >= 0).all()
    assert np.isfinite(out.resp_sum).all()


def test_metrics_aggregation():
    rng = np.random.default_rng(2)
    rates = rng.poisson(3000, 240).astype(np.float32)
    out = _run(rates)
    m = M.aggregate(out)
    assert 0.0 <= m.slo_violation_rate <= 1.0
    assert m.replica_minutes > 0
    assert m.p99_response_ms >= m.p95_response_ms >= 0
    assert m.total_requests == pytest.approx(out.served.sum())
