"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


# 24-point interpret-mode sweep (~90 s on CPU): nightly tier. Tier-1
# keeps kernel/oracle parity via test_kernel_properties.py's randomized
# shapes plus the tile-invariance and fused-pipeline tests below.
@pytest.mark.slow
@pytest.mark.parametrize("n", [1, 7, 256, 300])
@pytest.mark.parametrize("w", [60, 48, 64])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_window_features_sweep(n, w, dtype):
    rng = np.random.default_rng(n * 100 + w)
    x = rng.gamma(2.0, 10.0, size=(n, w)).astype(dtype)
    if n > 3:
        x[3, :] = 0.0                    # all-zero window
        x[2, w // 2] = 1e5               # spike
    got = np.asarray(ops.window_features(jnp.asarray(x), interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(
        x.astype(np.float32))))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("tile_n", [32, 128])
def test_window_features_tile_invariance(tile_n):
    rng = np.random.default_rng(0)
    x = rng.gamma(2.0, 10.0, size=(100, 60)).astype(np.float32)
    a = np.asarray(ops.window_features(jnp.asarray(x), tile_n=tile_n,
                                       interpret=True))
    b = np.asarray(ops.window_features(jnp.asarray(x), tile_n=256,
                                       interpret=True))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_fused_features_match_reference_pipeline():
    from repro.core.features import extract_features
    rng = np.random.default_rng(1)
    x = rng.gamma(2.0, 20.0, size=(64, 60)).astype(np.float32)
    got = np.asarray(ops.extract_features_fused(jnp.asarray(x),
                                                interpret=True))
    want = np.asarray(extract_features(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("b", [1, 5, 8, 17])
@pytest.mark.parametrize("t", [60, 500, 1440])
@pytest.mark.parametrize("period", [24, 60])
def test_holt_winters_sweep(b, t, period):
    rng = np.random.default_rng(b * 1000 + t)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=period,
                                      interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=period))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_holt_winters_dtype_f64_input():
    rng = np.random.default_rng(9)
    y = rng.gamma(2.0, 5.0, size=(3, 200))
    got = np.asarray(ops.holt_winters(jnp.asarray(y), interpret=True))
    want = np.asarray(ref.holt_winters_ref(
        jnp.asarray(y.astype(np.float32))))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
