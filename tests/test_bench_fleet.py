"""The bench_fleet decade-sweep contract: the smoke tier proves the
records and BENCH_fleet.json schema (what CI uploads as an artifact);
the nightly slow tier runs the full W sweep and asserts the memory
acceptance bar (W=1e5 in one dispatch, peak RSS < 2x the W=1e4 run)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(tmp_path, *args):
    cmd = [sys.executable, "-m", "benchmarks.run", "fleet",
           "--json", str(tmp_path), *args]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    subprocess.run(cmd, check=True, cwd=REPO, timeout=3000, env=env)
    with open(tmp_path / "BENCH_fleet.json") as f:
        return json.load(f)


def _check_doc(doc, *, smoke):
    assert doc["bench"] == "fleet" and doc["smoke"] is smoke
    assert not doc["failed"]
    names = [r["name"] for r in doc["records"]]
    assert names == ["fleet_decades", "fleet_stream"]
    for r in doc["records"]:
        assert set(r) == {"name", "us_per_call", "derived"}
        assert r["us_per_call"] > 0
    assert doc["records"][0]["derived"].startswith("w")


@pytest.mark.slow
def test_bench_fleet_smoke_json_schema(tmp_path):
    """The CI smoke invocation end-to-end: stable record names, stable
    schema."""
    _check_doc(_run(tmp_path, "--smoke"), smoke=True)


@pytest.mark.slow
def test_bench_fleet_full_decades(tmp_path):
    """Nightly: the full W in {64, 1e2, 1e3, 1e4, 1e5} sweep, pinning
    the acceptance criteria — W=1e5 completes in ONE dispatch and its
    peak RSS stays under 2x the W=1e4 run (the streamed O(bins)
    reductions keep accumulator memory W-independent)."""
    _check_doc(_run(tmp_path), smoke=False)
    with open(REPO / "experiments/bench/fleet_decades.json") as f:
        payload = json.load(f)
    per_w = {int(k): v for k, v in payload["per_w"].items()}
    assert set(per_w) == {64, 100, 1_000, 10_000, 100_000}
    assert per_w[100_000]["dispatches"] == 1
    assert (per_w[100_000]["peak_rss_mb"]
            < 2.0 * per_w[10_000]["peak_rss_mb"]), per_w
    assert payload["rss_ratio_1e5_vs_1e4"] < 2.0
