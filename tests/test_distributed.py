"""Multi-device integration tests (subprocess with forced host devices so
the main test process keeps a single device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_in_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    code = textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config, smoke_config
        from repro.dist import sharding as shd
        from repro.models import model as M
        from repro.train import optimizer as opt_lib
        from repro.train.train_step import make_train_step

        cfg = smoke_config(get_config("internlm2_1_8b"))
        params = M.init(jax.random.PRNGKey(0), cfg)
        opt = opt_lib.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        ts = make_train_step(cfg, remat=False)

        # single device
        p1, o1, m1 = jax.jit(ts)(params, opt, batch)

        # 4x2 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shd.set_mesh(mesh)
        in_sh = (shd.param_shardings(params),
                 type(opt)(None, shd.param_shardings(opt.master),
                           shd.param_shardings(opt.m),
                           shd.param_shardings(opt.v)),
                 shd.batch_shardings(batch))
        with mesh:
            p2, o2, m2 = jax.jit(ts, in_shardings=in_sh)(params, opt,
                                                         batch)
        print(json.dumps({"l1": float(m1["loss"]),
                          "l2": float(m2["loss"])}))
    """)
    res = _run_in_subprocess(code)
    assert abs(res["l1"] - res["l2"]) < 0.05, res


@pytest.mark.slow
def test_moe_ep_matches_scatter_path():
    code = textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist import sharding as shd
        from repro.models.common import ModelConfig
        from repro.models.moe import init_moe, moe_block, moe_block_scatter

        cfg = ModelConfig(name="t", family="moe_gqa", n_layers=1,
                          d_model=16, n_heads=4, d_ff=32, vocab=8,
                          n_experts=8, top_k=2, d_ff_expert=32,
                          capacity_factor=8.0, dtype="float32")
        p = init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))

        ref, _ = moe_block_scatter(p, x, cfg)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shd.set_mesh(mesh)
        with mesh:
            out, _ = jax.jit(lambda p, x: moe_block(p, x, cfg))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        print(json.dumps({"err": err}))
    """)
    res = _run_in_subprocess(code)
    assert res["err"] < 1e-3, res


@pytest.mark.slow
def test_elastic_restore_onto_different_mesh(tmp_path):
    code = textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ckpt

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh1 = jax.make_mesh((8,), ("data",))
        sh1 = {"w": NamedSharding(mesh1, P("data", None))}
        placed = jax.device_put(tree, sh1)
        ckpt.save("%s", 1, placed)

        # restore onto a *different* topology (2x2 submesh, model axis)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
        restored, step = ckpt.restore("%s", jax.eval_shape(lambda: tree),
                                      shardings=sh2)
        ok = bool(np.array_equal(np.asarray(restored["w"]),
                                 np.asarray(tree["w"])))
        n_shards = len(restored["w"].addressable_shards)
        print(json.dumps({"ok": ok, "n_shards": n_shards}))
    """ % (tmp_path, tmp_path))
    res = _run_in_subprocess(code)
    assert res["ok"] and res["n_shards"] == 4, res
