"""Fleet-scale evaluation: pooled in-scan reductions, W-chunked
execution, the streaming donated fold, loader chunk feeds, and the
8-virtual-device sharded-vs-unsharded parity pins (subprocess; tier-1 —
the acceptance bar for the sharded evaluation plane)."""
import json
import os
import subprocess
import sys
import textwrap
import types

import numpy as np
import pytest

import jax.numpy as jnp

from repro.aapaset.loader import AAPAsetLoader
from repro.dist import sharding as shd
from repro.evals import fleet, matrix
from repro.evals import metrics as EM
from repro.forecast import backtest
from repro.scaling import batch, registry, scenarios
from repro.sim.cluster import SimConfig, make_simulator

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

Q_RTOL = 2.5 * EM.quantile_rel_bound()

FLEET_SPEC = fleet.spec("t_fleet", policies=("hpa", "predictive"),
                        scenario="burst_storm", n_workloads=8, w_chunk=4,
                        minutes=40, seed=3)


def _close(a, b, *, rtol):
    """Field-wise EpisodeMetrics comparison; quantiles get the histogram
    half-bin bound (they snap to bin representatives, so tiny weight
    shifts can move them a whole bin)."""
    for field in EM.EpisodeMetrics._fields:
        tol = max(rtol, Q_RTOL) if field.startswith(("p95", "p99")) \
            else rtol
        np.testing.assert_allclose(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            rtol=tol, atol=1e-3, err_msg=field)


# ------------------------------------------------ pooled in-scan accums ----
def test_pooled_accum_matches_per_workload_sum():
    """per_workload=False streams the W reduction inside the scan; it
    must agree with the materialize-then-pool path (same adds, different
    f32 order) within host tolerance."""
    cfg = SimConfig()
    sc = scenarios.get("burst_storm", n_workloads=6, minutes=40, seed=0)
    ctrls = [registry.get_controller(n, cfg) for n in ("hpa", "kpa")]
    pool_ref, per_w = matrix.evaluate_controllers(ctrls, sc.rates, cfg)
    pool_stream, none = matrix.evaluate_controllers(
        ctrls, sc.rates, cfg, per_workload=False)
    assert none is None
    assert np.asarray(per_w.served if hasattr(per_w, "served") else
                      per_w.total_requests).shape == (2, 6)
    _close(pool_stream, pool_ref, rtol=2e-4)


def test_accum_update_pooled_equals_summed_updates():
    """Unit pin: one pooled fold over [W] MinuteOut == W scalar folds
    summed — the streaming reduction only reorders f32 adds."""
    import jax
    from repro.sim.cluster import MinuteOut
    rng = np.random.default_rng(0)
    edges = EM.response_edges(64, 600.0)
    W = 4
    fields = {f: jnp.asarray(rng.gamma(2.0, 10.0, (W,)), jnp.float32)
              for f in MinuteOut._fields}
    pooled = EM.accum_update_pooled(EM.accum_init(64),
                                    MinuteOut(**fields), edges)
    summed = EM.accum_init(64)
    for w in range(W):
        one = EM.accum_update(EM.accum_init(64),
                              MinuteOut(**{f: fields[f][w]
                                           for f in MinuteOut._fields}),
                              edges)
        summed = jax.tree.map(jnp.add, summed, one)
    for f in EM.MetricAccum._fields:
        np.testing.assert_allclose(np.asarray(getattr(pooled, f)),
                                   np.asarray(getattr(summed, f)),
                                   rtol=1e-6, err_msg=f)


# --------------------------------------------------------- fleet runner ----
def test_fleet_one_dispatch_matches_stream():
    """The single-dispatch chunk scan and the donated streaming fold run
    the same compiled chunk body in the same order — compiled-program
    tolerance applies."""
    res = fleet.run_fleet(FLEET_SPEC)
    res_s = fleet.run_fleet(FLEET_SPEC, stream=True)
    assert res.meta["dispatches"] == 1
    assert res_s.meta["dispatches"] == FLEET_SPEC.n_chunks
    _close(res.pooled, res_s.pooled, rtol=2e-6)
    np.testing.assert_allclose(np.asarray(res.rei.rei),
                               np.asarray(res_s.rei.rei), rtol=2e-6)


def test_fleet_matches_controller_evaluator():
    """The fleet's chunked pooled metrics agree with the unchunked
    pooled evaluator on the SAME rates (chunking only reorders the f32
    pooling adds)."""
    rates = fleet.build_rates(FLEET_SPEC)            # [C, Wc, M]
    W, M = FLEET_SPEC.n_workloads, FLEET_SPEC.minutes
    flat = rates.reshape(W, M)
    ctrls = fleet.controllers(FLEET_SPEC)
    pool_ref, _ = matrix.evaluate_controllers(
        ctrls, flat, FLEET_SPEC.sim_config(), per_workload=False)
    res = fleet.run_fleet(FLEET_SPEC)
    _close(res.pooled, pool_ref, rtol=2e-4)
    assert res.meta["workloads"] == W
    assert res.meta["lane_minutes_per_sec"] > 0


def test_fleet_spec_validates_chunking():
    with pytest.raises(ValueError, match="must divide"):
        fleet.spec("bad", policies=("hpa",), n_workloads=10, w_chunk=4)


def test_fleet_chunk_rates_deterministic():
    a = fleet.chunk_rates(FLEET_SPEC, 1)
    b = fleet.chunk_rates(FLEET_SPEC, 1)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (FLEET_SPEC.w_chunk, FLEET_SPEC.minutes)
    # distinct chunks draw distinct workloads
    assert not np.array_equal(a, fleet.chunk_rates(FLEET_SPEC, 0))


# ------------------------------------------------- chunked simulators ----
def test_batch_simulator_w_chunk_parity():
    cfg = SimConfig()
    sc = scenarios.get("idle_wake", n_workloads=8, minutes=30, seed=1)
    ctrls = [registry.get_controller(n, cfg) for n in ("hpa", "kpa")]
    full = batch.make_batch_simulator(ctrls, cfg)(jnp.asarray(sc.rates))
    chunked = batch.make_batch_simulator(ctrls, cfg, w_chunk=4)(
        jnp.asarray(sc.rates))
    for f in full._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(chunked, f)), np.asarray(getattr(full, f)),
            rtol=1e-5, atol=1e-5, err_msg=f)
    with pytest.raises(ValueError, match="must divide"):
        batch.make_batch_simulator(ctrls, cfg, w_chunk=3)(
            jnp.asarray(sc.rates))


def test_make_simulator_w_chunk_and_donate():
    cfg = SimConfig()
    sc = scenarios.get("burst_storm", n_workloads=6, minutes=30, seed=2)
    ctrl = registry.get_controller("hpa", cfg)
    full = make_simulator(ctrl, cfg)(jnp.asarray(sc.rates))
    chunked = make_simulator(ctrl, cfg, w_chunk=2, donate=True)(
        jnp.asarray(sc.rates))
    for f in full._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(chunked, f)), np.asarray(getattr(full, f)),
            rtol=1e-5, atol=1e-5, err_msg=f)


# ----------------------------------------------------- loader fleet feed ----
def _fake_loader(F=5, T=50) -> AAPAsetLoader:
    series = np.arange(F * T, dtype=np.float32).reshape(F, T)
    return AAPAsetLoader(data=types.SimpleNamespace(series=series),
                         manifest={})


def test_loader_rate_chunks_deterministic_and_sharded():
    ld = _fake_loader()
    a = list(ld.rate_chunks(8, 2, minutes=20, seed=7))
    b = list(ld.rate_chunks(8, 2, minutes=20, seed=7))
    assert len(a) == 4 and all(c.shape == (2, 20) for c in a)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)

    # shards partition the chunk stream disjointly and exhaustively
    s0 = list(ld.rate_chunks(8, 2, minutes=20, seed=7, shard_index=0,
                             num_shards=2))
    s1 = list(ld.rate_chunks(8, 2, minutes=20, seed=7, shard_index=1,
                             num_shards=2))
    assert len(s0) == len(s1) == 2
    np.testing.assert_array_equal(np.stack(a),
                                  np.stack([s0[0], s1[0], s0[1], s1[1]]))

    with pytest.raises(ValueError, match="must divide"):
        next(ld.rate_chunks(7, 2))
    with pytest.raises(ValueError, match="out of range"):
        next(ld.rate_chunks(8, 2, shard_index=2, num_shards=2))


def test_loader_rate_chunks_feed_fleet_stream():
    ld = _fake_loader(F=4, T=FLEET_SPEC.minutes)
    res = fleet.run_fleet(FLEET_SPEC, stream=True,
                          chunks=ld.rate_chunks(FLEET_SPEC.n_workloads,
                                                FLEET_SPEC.w_chunk,
                                                seed=0))
    assert res.meta["workloads"] == FLEET_SPEC.n_workloads
    assert np.all(np.isfinite(np.asarray(res.pooled.slo_violation_rate)))


# --------------------------------------------------- backtest b_chunk ----
def test_backtest_b_chunk_bit_exact():
    """Chunked backtests (including a padded tail) are bit-identical to
    the unchunked [F, B, T] path — each series' lane is independent."""
    rng = np.random.default_rng(0)
    y = rng.gamma(2.0, 50.0, (8, 40)).astype(np.float32)
    fcs = ("ewma", "holt_winters")
    ref = np.asarray(backtest.batch_smooth(fcs, y))
    chunked = np.asarray(backtest.batch_smooth(fcs, y, b_chunk=3))
    np.testing.assert_array_equal(chunked, ref)
    with pytest.raises(ValueError, match="positive"):
        backtest.batch_smooth(fcs, y, b_chunk=0)


# ------------------------------------------------- sharding (1 device) ----
def test_lane_sharding_none_without_mesh():
    assert shd.active() is None
    assert shd.lane_sharding((2, 16, 30)) is None
    # constraints are no-ops too: the sharded step runs on one device
    x = jnp.ones((4, 8))
    np.testing.assert_array_equal(np.asarray(shd.constrain(x, (None, "dp"))),
                                  np.asarray(x))


# ------------------------------------- 8-virtual-device parity (tier-1) ----
def _run_in_subprocess(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_matrix_matches_unsharded_8dev():
    """THE acceptance pin: the same matrix runner under an 8-device dp
    mesh is bit-close (rtol 2e-6) to the unsharded path — pooled metrics
    and REI — and still compiles exactly once. Also pins the strict=/
    warn-once spec semantics and lane_sharding, which need real multi-
    device axis sizes."""
    code = textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.dist import sharding as shd
        from repro.evals import matrix
        from repro.evals import rei as ER

        spec = matrix.spec(
            "t_shard", policies=("hpa", "predictive"),
            scenarios=(("burst_storm", {}),), seeds=(0,),
            n_workloads=8, minutes=60)
        rates = matrix.build_rates(spec)

        def score(pool):
            return ER.rei(pool.slo_violation_rate, pool.replica_minutes,
                          pool.scaling_actions, minutes=spec.minutes,
                          n_workloads=spec.n_workloads).rei

        # unsharded reference (no active mesh: constraints are no-ops)
        pool1, _ = matrix.make_runner(spec)(rates)
        rei1 = score(pool1)

        # 8-way dp mesh; input placed with lane_sharding (W axis = 2)
        mesh = jax.make_mesh((8,), ("data",))
        rules = shd.set_mesh(mesh)
        sh = shd.lane_sharding(rates.shape, w_axis=2, strict=True)
        assert sh.spec == P(None, None, "data", None), sh.spec
        placed = jax.device_put(jnp.asarray(rates, jnp.float32), sh)
        runner = matrix.make_runner(spec)
        with mesh:
            pool2, _ = runner(placed)
            rei2 = score(pool2)
        one_compile = runner._cache_size() == 1
        n_shards = len(pool2.slo_violation_rate.addressable_shards) >= 1

        # the compiled program really sharded: the per-lane plant state
        # is [P, W] with W=8 over 8 devices
        err = max(float(np.max(np.abs(np.asarray(getattr(pool1, f))
                                      - np.asarray(getattr(pool2, f)))
                               / np.maximum(np.abs(
                                   np.asarray(getattr(pool1, f))), 1e-9)))
                  for f in ("slo_violation_rate", "mean_response_ms",
                            "replica_minutes", "avg_cpu_util",
                            "scaling_actions", "total_requests"))
        rei_err = float(np.max(np.abs(np.asarray(rei1)
                                      - np.asarray(rei2))))

        # quantiles snap to bin representatives: equal bins, not rtol
        q_equal = bool(
            np.array_equal(np.asarray(pool1.p95_response_ms),
                           np.asarray(pool2.p95_response_ms))
            and np.array_equal(np.asarray(pool1.p99_response_ms),
                               np.asarray(pool2.p99_response_ms)))

        # strict=/warn-once semantics need a real >1 axis size
        strict_raises = False
        try:
            rules.spec(("dp",), (10,), strict=True)
        except ValueError:
            strict_raises = True
        import repro.dist.sharding as S
        n_warn0 = len(S._WARNED)
        rules.spec(("dp",), (10,))
        rules.spec(("dp",), (10,))
        warn_once = (len(S._WARNED) - n_warn0) == 1
        replicated = rules.spec(("dp",), (10,)) == P(None)

        print(json.dumps({
            "err": err, "rei_err": rei_err, "q_equal": q_equal,
            "one_compile": one_compile, "n_shards": n_shards,
            "strict_raises": strict_raises, "warn_once": warn_once,
            "replicated": replicated,
            "n_devices": jax.device_count()}))
    """)
    res = _run_in_subprocess(code)
    assert res["n_devices"] == 8, res
    assert res["err"] < 2e-6, res
    assert res["rei_err"] < 2e-6, res
    assert res["q_equal"], res
    assert res["one_compile"], res
    assert res["strict_raises"] and res["warn_once"] and res["replicated"], \
        res


def test_sharded_fleet_matches_unsharded_8dev():
    """The fleet runner's one-dispatch chunk scan under the mesh: pooled
    [P] metrics bit-close to the single-device run."""
    code = textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist import sharding as shd
        from repro.evals import fleet

        spec = fleet.spec("t_fleet_shard", policies=("hpa",),
                          scenario="burst_storm", n_workloads=32,
                          w_chunk=16, minutes=40, seed=0)
        res1 = fleet.run_fleet(spec)

        mesh = jax.make_mesh((8,), ("data",))
        shd.set_mesh(mesh)
        with mesh:
            res2 = fleet.run_fleet(spec)

        err = max(float(np.max(np.abs(
            np.asarray(getattr(res1.pooled, f))
            - np.asarray(getattr(res2.pooled, f)))
            / np.maximum(np.abs(np.asarray(getattr(res1.pooled, f))),
                         1e-9)))
            for f in ("slo_violation_rate", "mean_response_ms",
                      "replica_minutes", "total_requests"))
        print(json.dumps({
            "err": err,
            "one_dispatch": res2.meta["dispatches"] == 1,
            "mesh": res2.meta["mesh"],
            "n_devices": jax.device_count()}))
    """)
    res = _run_in_subprocess(code)
    assert res["n_devices"] == 8, res
    assert res["one_dispatch"], res
    assert res["mesh"] == {"data": 8}, res
    assert res["err"] < 2e-6, res
