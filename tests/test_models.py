"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step + prefill/decode, asserting output shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.models import model as M


# Heavyweight model/train/system tier: nightly CI runs these; tier-1 deselects
# with -m 'not slow'.
pytestmark = pytest.mark.slow

B, S = 2, 32


def _batch(cfg, with_labels=True):
    n_text = S - (cfg.n_img_tokens or 0)
    batch = {"tokens": jnp.ones((B, n_text), jnp.int32)}
    if with_labels:
        batch["labels"] = jnp.ones((B, n_text), jnp.int32)
    if cfg.n_img_tokens:
        batch["img_embeds"] = jnp.zeros((B, cfg.n_img_tokens, cfg.d_model),
                                        cfg.jdtype)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                        cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch):
    cfg = smoke_config(get_config(arch))
    params = M.init(jax.random.PRNGKey(0), cfg)
    n_text = S - (cfg.n_img_tokens or 0)

    loss, parts = M.loss_fn(params, _batch(cfg), cfg)
    assert np.isfinite(float(loss))
    logits, aux = M.forward(params, _batch(cfg), cfg)
    assert logits.shape == (B, n_text, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    lg, cache = M.prefill(params, _batch(cfg, with_labels=False), cfg,
                          max_len=64)
    assert lg.shape == (B, 1, cfg.vocab)
    pos = jnp.int32(n_text + (cfg.n_img_tokens or 0))
    lg2, cache = M.decode_step(params, cache,
                               jnp.ones((B, 1), jnp.int32), pos, cfg)
    assert lg2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg2, np.float32)))


@pytest.mark.parametrize("arch", ["stablelm_1_6b", "mamba2_2_7b",
                                  "zamba2_2_7b"])
def test_prefill_decode_consistency(arch):
    """Decoding token-by-token equals prefill at the same positions."""
    cfg = smoke_config(get_config(arch))
    params = M.init(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 8), 0, cfg.vocab)

    # the hybrid's SSM recurrence accumulates bf16 rounding differently
    # between the full-sequence scan and the stepwise decode path
    atol = 0.1 if arch == "zamba2_2_7b" else 0.05

    logits_full, _ = M.forward(params, {"tokens": toks}, cfg, remat=False)

    lg, cache = M.prefill(params, {"tokens": toks[:, :4]}, cfg, max_len=16)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(logits_full[:, 3], np.float32), rtol=0.05, atol=atol)
    for t in range(4, 8):
        lg, cache = M.decode_step(params, cache, toks[:, t:t + 1],
                                  jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(logits_full[:, t], np.float32), rtol=0.05,
            atol=atol)


def test_param_counts_match_configs():
    """Full configs' parameter counts are near their nominal sizes."""
    expect = {"stablelm_1_6b": 1.6e9, "deepseek_67b": 67e9,
              "mistral_nemo_12b": 12e9, "internlm2_1_8b": 1.8e9,
              "mamba2_2_7b": 2.7e9, "deepseek_v2_lite_16b": 16e9,
              "qwen3_moe_30b_a3b": 30e9}
    for arch, nominal in expect.items():
        n = get_config(arch).param_count()
        assert 0.55 * nominal < n < 1.55 * nominal, (arch, n, nominal)


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3_moe_30b_a3b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()
