"""The `decide_kernel=` dispatch contract (ISSUE: whole-episode-on-chip).

Three guarantees, mirrored from the `plant_kernel=` flag:

* **off path is bit-exact** — `decide_kernel=False` and the CPU default
  (auto-off on non-TPU backends) produce byte-identical MinuteOut: the
  flag cannot perturb the published eval numbers.
* **on path is one compile** — the fused episode kernel replaces the
  whole episode loop, so `make_simulator(decide_kernel=True)` still
  shows `_cache_size() == 1` after running, and composes with
  `w_chunk` in the batch front door.
* **telemetry is loudly incompatible** — decisions never leave the chip
  on the fused path, so `telemetry=True` raises at build time (both
  `cluster.make_simulator` and `scaling.batch.make_batch_simulator`)
  instead of silently returning empty traces.

The interpret-mode fused-vs-oracle parity itself is pinned per policy in
test_kernel_smoke.py; the `requires_tpu` test at the bottom re-pins it
with `interpret=False` on real hardware.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.scaling import batch, registry
from repro.sim.cluster import SimConfig, make_simulator, simulate

# ci=30: small unrolled-tick jaxpr -> seconds-scale interpret compiles.
CFG = SimConfig(control_interval_sec=30)


def _rates(w=5, m=6, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 200.0, size=(w, m)), jnp.float32)


def _ctrl(name="hpa"):
    return registry.get_controller(name, CFG)


def test_off_path_bit_exact_vs_default():
    rates = _rates()
    explicit = make_simulator(_ctrl(), CFG, decide_kernel=False)(rates)
    default = make_simulator(_ctrl(), CFG)(rates)  # CPU -> auto off
    for i, (a, e) in enumerate(zip(explicit, default)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(e),
                                      err_msg=f"MinuteOut[{i}]")


def test_fused_simulator_one_compile_and_parity():
    rates = _rates()
    fused = make_simulator(_ctrl(), CFG, decide_kernel=True)
    got = fused(rates)
    assert fused._cache_size() == 1
    want = make_simulator(_ctrl(), CFG, decide_kernel=False)(rates)
    for i, (a, e) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"MinuteOut[{i}]")


def test_fused_single_episode_simulate():
    r = _rates(w=1)[0]
    got = simulate(r, _ctrl(), CFG, decide_kernel=True)
    want = simulate(r, _ctrl(), CFG, decide_kernel=False)
    for i, (a, e) in enumerate(zip(got, want)):
        assert a.shape == e.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"MinuteOut[{i}]")


def test_batch_fused_parity_and_w_chunk():
    rates = _rates(w=10)
    ctrls = [_ctrl("hpa"), _ctrl("kpa")]
    on = batch.make_batch_simulator(ctrls, CFG, decide_kernel=True)
    off = batch.make_batch_simulator(ctrls, CFG, decide_kernel=False)
    got, want = on(rates), off(rates)
    assert on._cache_size() == 1
    for i, (a, e) in enumerate(zip(got, want)):
        assert a.shape == (2, 10, rates.shape[1])
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"MinuteOut[{i}]")
    chunked = batch.make_batch_simulator(ctrls, CFG, decide_kernel=True,
                                         w_chunk=5)(rates)
    for i, (a, e) in enumerate(zip(chunked, got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"w_chunk MinuteOut[{i}]")


def test_telemetry_rejected_on_fused_path():
    with pytest.raises(ValueError, match="decide_kernel"):
        make_simulator(_ctrl(), CFG, decide_kernel=True, telemetry=True)
    with pytest.raises(ValueError, match="decide_kernel"):
        simulate(_rates(w=1)[0], _ctrl(), CFG, decide_kernel=True,
                 telemetry=True)
    with pytest.raises(ValueError, match="decide_kernel"):
        batch.make_batch_simulator([_ctrl()], CFG, decide_kernel=True,
                                   telemetry=True)


def test_telemetry_w_chunk_error_names_fleet_front_door():
    """The telemetry+w_chunk rejection must point at the actual recourse:
    FleetSpec(..., trace_lanes=K) via repro.evals.fleet."""
    with pytest.raises(ValueError) as ei:
        batch.make_batch_simulator([_ctrl()], CFG, telemetry=True,
                                   w_chunk=4)
    msg = str(ei.value)
    assert "trace_lanes" in msg and "evals.fleet" in msg


@pytest.mark.requires_tpu
def test_fused_compiled_parity_on_tpu():
    """interpret=False (Mosaic-compiled) fused episode vs the CPU blocked
    scan, for the non-fft policies (AAPA's rfft reclassify features are
    not Mosaic-lowerable yet; see the episode_block docstring)."""
    from repro.kernels import ops, ref
    rates = _rates()
    for name in ("hpa", "kpa", "predictive"):
        ctrl = registry.get_controller(name, CFG)
        got = ops.episode_block(rates, ctrl, CFG, interpret=False)
        want = ref.episode_block_ref(rates, ctrl, CFG)
        for i, (a, e) in enumerate(zip(got, want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                       rtol=1e-4, atol=1e-3,
                                       err_msg=f"{name} MinuteOut[{i}]")
