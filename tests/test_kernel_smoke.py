"""Deterministic tier-1 kernel/oracle parity smoke (interpret mode).

The parametrized sweeps in test_kernels.py are nightly (`slow`) and
test_kernel_properties.py degrades to seeded replay without hypothesis —
this file is the per-PR floor: one fixed small shape per Pallas kernel
(`plant_block`, `window_features`, `holt_winters`), seconds to run, so a
kernel regression is caught in the same CI pass that introduced it.
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref


def test_window_features_small_shape_parity():
    rng = np.random.default_rng(42)
    x = rng.gamma(2.0, 10.0, size=(8, 60)).astype(np.float32)
    x[0, :] = 0.0                        # all-zero window
    x[4, 30] = 1e5                       # spike outlier
    got = np.asarray(ops.window_features(jnp.asarray(x), interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_holt_winters_small_shape_parity():
    rng = np.random.default_rng(7)
    y = rng.gamma(2.0, 5.0, size=(4, 120)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=24,
                                      interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=24))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_plant_block_small_shape_parity():
    rng = np.random.default_rng(3)
    b, s, n_ticks = 4, 30, 15
    pipeline = rng.gamma(1.0, 0.6, (b, s)).astype(np.float32)
    state = dict(
        ready=rng.gamma(2.0, 2.0, b).astype(np.float32),
        pipeline=pipeline,
        queue=rng.gamma(1.0, 25.0, b).astype(np.float32),
        wait_sum=rng.gamma(1.0, 5.0, b).astype(np.float32),
        util_ema=rng.random(b).astype(np.float32),
        cooldown=rng.uniform(0.0, 20.0, b).astype(np.float32),
        pipe_sum=pipeline.sum(axis=1).astype(np.float32),
        arrivals=rng.gamma(2.0, 30.0, b).astype(np.float32))
    args = [jnp.asarray(v) for v in state.values()]
    ks, kt = ops.plant_tick_block(*args, n_ticks=n_ticks, interpret=True)
    rs, rt = ref.plant_block_ref(*args, n_ticks=n_ticks)
    for i, (a, e) in enumerate(zip(ks, rs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"state[{i}]")
    for i, (a, e) in enumerate(zip(kt, rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"ticks[{i}]")
