"""Deterministic tier-1 kernel/oracle parity smoke (interpret mode).

The parametrized sweeps in test_kernels.py are nightly (`slow`) and
test_kernel_properties.py degrades to seeded replay without hypothesis —
this file is the per-PR floor: one fixed small shape per Pallas kernel
(`plant_block`, `window_features`, `holt_winters`, the fused-decide
`episode_block` for every registry policy, and the GBDT node-table
kernel), seconds to run, so a kernel regression is caught in the same CI
pass that introduced it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gbdt
from repro.kernels import ops, ref
from repro.scaling import registry
from repro.sim.cluster import SimConfig


def _tiny_gbdt():
    """A real (tiny) trained GBDT so the AAPA-family smoke exercises
    actual node-table inference inside the kernel, not the constant
    fallback classifier."""
    rng = np.random.default_rng(11)
    X = rng.normal(size=(96, 38)).astype(np.float32)
    y = rng.integers(0, 4, 96).astype(np.int32)
    return gbdt.fit(X, y, gbdt.GBDTConfig(n_rounds=4, depth=3))


def _gbdt_classify(params):
    def classify(feats):
        logits = gbdt.predict_logits(params, feats[None, :])[0]
        p = jax.nn.softmax(logits)
        return jnp.argmax(p).astype(jnp.int32), jnp.max(p).astype(
            jnp.float32)
    return classify


def test_window_features_small_shape_parity():
    rng = np.random.default_rng(42)
    x = rng.gamma(2.0, 10.0, size=(8, 60)).astype(np.float32)
    x[0, :] = 0.0                        # all-zero window
    x[4, 30] = 1e5                       # spike outlier
    got = np.asarray(ops.window_features(jnp.asarray(x), interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_holt_winters_small_shape_parity():
    rng = np.random.default_rng(7)
    y = rng.gamma(2.0, 5.0, size=(4, 120)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=24,
                                      interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=24))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_plant_block_small_shape_parity():
    rng = np.random.default_rng(3)
    b, s, n_ticks = 4, 30, 15
    pipeline = rng.gamma(1.0, 0.6, (b, s)).astype(np.float32)
    state = dict(
        ready=rng.gamma(2.0, 2.0, b).astype(np.float32),
        pipeline=pipeline,
        queue=rng.gamma(1.0, 25.0, b).astype(np.float32),
        wait_sum=rng.gamma(1.0, 5.0, b).astype(np.float32),
        util_ema=rng.random(b).astype(np.float32),
        cooldown=rng.uniform(0.0, 20.0, b).astype(np.float32),
        pipe_sum=pipeline.sum(axis=1).astype(np.float32),
        arrivals=rng.gamma(2.0, 30.0, b).astype(np.float32))
    args = [jnp.asarray(v) for v in state.values()]
    ks, kt = ops.plant_tick_block(*args, n_ticks=n_ticks, interpret=True)
    rs, rt = ref.plant_block_ref(*args, n_ticks=n_ticks)
    for i, (a, e) in enumerate(zip(ks, rs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"state[{i}]")
    for i, (a, e) in enumerate(zip(kt, rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"ticks[{i}]")


# ci=30 keeps the unrolled-tick jaxpr small (the 29-tick remainder goes
# through lax.scan) so each policy compiles in seconds under interpret.
_EP_CFG = SimConfig(control_interval_sec=30)


@pytest.mark.parametrize("policy", registry.available())
def test_episode_block_policy_parity(policy):
    """Fused-decide episode kernel == CPU blocked-scan oracle for every
    registry policy, on a lane count (5) that does not divide the tile
    (4). AAPA-family policies run a real tiny GBDT classifier with
    stride_min=2 so in-kernel reclassification fires mid-episode."""
    rng = np.random.default_rng(5)
    rates = jnp.asarray(rng.uniform(0.0, 200.0, size=(5, 6)), jnp.float32)
    kw = {}
    if registry.spec(policy).needs_classifier:
        kw = dict(classify=_gbdt_classify(_tiny_gbdt()), stride_min=2)
    ctrl = registry.get_controller(policy, _EP_CFG, **kw)
    got = ops.episode_block(rates, ctrl, _EP_CFG, tile_b=4,
                            interpret=True)
    want = ref.episode_block_ref(rates, ctrl, _EP_CFG)
    for i, (a, e) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"{policy} MinuteOut[{i}]")


def test_gbdt_tables_small_shape_parity():
    """Node-table kernel is BIT-exact vs the host table path (identical
    traversal over the identical layout), on a row count that does not
    divide the tile."""
    params = _tiny_gbdt()
    rng = np.random.default_rng(23)
    X = jnp.asarray(rng.normal(size=(37, 38)).astype(np.float32))
    got = np.asarray(ops.gbdt_logits(params, X, tile_n=16,
                                     interpret=True))
    want = np.asarray(ref.gbdt_logits_ref(params, X))
    np.testing.assert_array_equal(got, want)
