"""Checkpointing (atomicity, retention, async, elastic restore) + training
substrate (AdamW descent, grad-accumulation equivalence)."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke_config
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train.train_step import make_train_step



# Heavyweight model/train/system tier: nightly CI runs these; tier-1 deselects
# with -m 'not slow'.
pytestmark = pytest.mark.slow

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.ones((3,), jnp.bfloat16),
                  "d": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 5, t)
    restored, step = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_and_retention(tmp_path):
    for s in [1, 2, 3, 4]:
        ckpt.save(tmp_path, s, _tree())
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.retain(tmp_path, keep=2)
    assert ckpt.latest_step(tmp_path) == 4
    with pytest.raises((AssertionError, FileNotFoundError)):
        # step 1 should be gone
        ckpt.restore(tmp_path, jax.eval_shape(_tree), step=1)


def test_tmp_dirs_ignored(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    os.makedirs(tmp_path / "step_00000009.tmp")   # simulated dead write
    assert ckpt.latest_step(tmp_path) == 1


def test_async_checkpointer(tmp_path):
    ac = ckpt.AsyncCheckpointer(tmp_path, keep=2)
    for s in range(3):
        ac.save(s, _tree(s))
    ac.close()
    assert ckpt.latest_step(tmp_path) == 2
    restored, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: _tree(2)))
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(_tree(2)["a"]))


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"a": jnp.ones((4,))})
    with pytest.raises(AssertionError):
        ckpt.restore(tmp_path, {"a": jax.ShapeDtypeStruct((5,),
                                                          jnp.float32)})


def _tiny_train(arch="internlm2_1_8b", steps=8, microbatches=1):
    cfg = smoke_config(get_config(arch))
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    ts = jax.jit(make_train_step(
        cfg, opt_lib.AdamWConfig(lr=1e-2, warmup_steps=1),
        microbatches=microbatches, remat=False))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(steps):
        params, opt_state, metrics = ts(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def test_adamw_decreases_loss():
    losses = _tiny_train()
    assert losses[-1] < losses[0] - 0.3


def test_grad_accumulation_equivalent():
    l1 = _tiny_train(steps=3, microbatches=1)
    l2 = _tiny_train(steps=3, microbatches=2)
    # same data, same seed: accumulated grads ~= full-batch grads
    np.testing.assert_allclose(l1, l2, rtol=2e-2, atol=2e-2)


def test_train_resume_from_checkpoint(tmp_path):
    cfg = smoke_config(get_config("internlm2_1_8b"))
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    ckpt.save(tmp_path, 0, {"params": params, "opt": opt_state})
    target = jax.eval_shape(lambda: {"params": params, "opt": opt_state})
    restored, step = ckpt.restore(tmp_path, target)
    ts = jax.jit(make_train_step(cfg, remat=False))
    toks = jnp.ones((2, 16), jnp.int32)
    p2, o2, m = ts(restored["params"], restored["opt"],
                   {"tokens": toks, "labels": toks})
    assert np.isfinite(float(m["loss"]))
