"""Algorithm 1 (uncertainty-aware adjustment) + REI metric."""
import jax.numpy as jnp
import pytest
from _hypo_compat import given, settings, st

from repro.core import rei as R
from repro.core import uncertainty as U


def test_algorithm1_exact_at_full_confidence():
    adj = U.adjust(1.0, jnp.float32(0.6), jnp.float32(7.0), jnp.float32(1))
    assert float(adj.target_cpu) == pytest.approx(0.6)
    assert float(adj.cooldown_min) == pytest.approx(7.0)
    assert float(adj.min_replicas) == 1.0


def test_algorithm1_paper_example():
    # c = 0.5: m = 1.25, cpu = 0.6*(1-0.1)=0.54, cool = 8.75, rep = ceil(2.5)
    adj = U.adjust(0.5, jnp.float32(0.6), jnp.float32(7.0), jnp.float32(2))
    assert float(adj.target_cpu) == pytest.approx(0.54)
    assert float(adj.cooldown_min) == pytest.approx(8.75)
    assert float(adj.min_replicas) == 3.0


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_lower_confidence_is_more_conservative(c1, c2):
    lo, hi = min(c1, c2), max(c1, c2)
    a_lo = U.adjust(lo, jnp.float32(0.6), jnp.float32(7.0), jnp.float32(2))
    a_hi = U.adjust(hi, jnp.float32(0.6), jnp.float32(7.0), jnp.float32(2))
    assert float(a_lo.target_cpu) <= float(a_hi.target_cpu) + 1e-6
    assert float(a_lo.cooldown_min) >= float(a_hi.cooldown_min) - 1e-6
    assert float(a_lo.min_replicas) >= float(a_hi.min_replicas)


def test_rei_formula():
    b = R.rei(violation_rate=0.1, pod_minutes=2880.0, scaling_actions=20.0)
    assert b.s_slo == pytest.approx(0.9)
    assert b.s_eff == pytest.approx(0.5)    # 2880/1440 = 2 -> 1/2
    assert b.s_stab == pytest.approx(0.5)   # 20/10 -> 1/2
    assert b.rei == pytest.approx(0.5 * 0.9 + 0.3 * 0.5 + 0.2 * 0.5)


def test_rei_bounded():
    b = R.rei(0.0, 1.0, 0.0)
    assert 0.0 <= b.rei <= 1.0
    b2 = R.rei(1.0, 1e9, 1e9)
    assert b2.rei == pytest.approx(0.0, abs=1e-6)


def test_rei_sensitivity_small():
    outs = R.sensitivity(0.05, 2000, 15)
    reis = [o.rei for o in outs]
    base = R.rei(0.05, 2000, 15).rei
    assert max(abs(r - base) for r in reis) < 0.1
