"""The unified scaling control plane: registry round-trips, batched
policies x workloads parity with the per-policy simulators, hyperparam
grid stacking, scenarios, shared cooldown semantics, and sim-vs-engine
adapter parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.scaling import api, batch, registry, scenarios
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig, make_simulator, simulate

CFG = SimConfig()


def _rates(shape, lam=1200, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).poisson(lam, shape).astype(np.float32))


# ------------------------------------------------------------- registry ----
def test_registry_round_trips_every_policy():
    rates = _rates(90, lam=900)
    for name in registry.available():
        ctrl = registry.get_controller(name, CFG)
        assert ctrl.name == name or name in ctrl.name
        out = simulate(rates, ctrl, CFG)
        assert np.isfinite(np.asarray(out.served)).all()
        assert float(out.served.sum()) > 0


def test_registry_rejects_unknown_policy_and_hyperparam():
    with pytest.raises(KeyError):
        registry.get_controller("nope", CFG)
    with pytest.raises(TypeError):
        registry.get_controller("hpa", CFG, warp_factor=9)


def test_registry_overrides_apply():
    lo = registry.get_controller("hpa", CFG, target=0.3)
    hi = registry.get_controller("hpa", CFG, target=0.95)
    rates = _rates(120, lam=6000)
    rep_lo = float(simulate(rates, lo, CFG).replica_seconds.sum())
    rep_hi = float(simulate(rates, hi, CFG).replica_seconds.sum())
    assert rep_lo > rep_hi  # lower CPU target -> more replicas


def test_backcompat_reexports():
    from repro.core.controllers import hpa_controller as old_hpa
    from repro.scaling.policies import hpa_controller as new_hpa
    from repro.sim.cluster import Controller, Obs
    assert old_hpa is new_hpa
    assert Controller is api.Controller and Obs is api.Obs


# ---------------------------------------------------------------- batch ----
def test_batch_simulate_matches_per_policy_simulators():
    """The single compiled policies x workloads scan reproduces each
    standalone make_simulator run (same seeds, allclose)."""
    rates = _rates((3, 120), lam=1500, seed=1)
    names = registry.available()
    ctrls = [registry.get_controller(n, CFG) for n in names]
    out = batch.batch_simulate(ctrls, rates, CFG)       # [P, W, M]
    assert out.served.shape == (len(ctrls), 3, 120)
    for i, ctrl in enumerate(ctrls):
        single = make_simulator(ctrl, CFG)(rates)
        for field in ("served", "violated", "cold_starts",
                      "replica_seconds", "ready_mean", "oscillations"):
            np.testing.assert_allclose(
                np.asarray(getattr(out, field)[i]),
                np.asarray(getattr(single, field)), rtol=1e-5, atol=1e-5,
                err_msg=f"{ctrl.name}.{field}")


def test_grid_simulator_matches_individual_factories():
    grid = [{"target": 0.5}, {"target": 0.7}, {"target": 0.9}]
    rates = _rates((2, 90), lam=2400, seed=2)
    out = batch.make_grid_simulator("hpa", grid, CFG)(rates)
    assert out.served.shape == (3, 2, 90)
    for i, g in enumerate(grid):
        single = make_simulator(
            registry.get_controller("hpa", CFG, **g), CFG)(rates)
        np.testing.assert_allclose(np.asarray(out.served[i]),
                                   np.asarray(single.served), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out.ready_mean[i]),
                                   np.asarray(single.ready_mean),
                                   rtol=1e-5)


def test_grid_simulator_rejects_unstackable_keys():
    with pytest.raises(TypeError):
        batch.make_grid_simulator("hpa", [{"stabilization_min": 3.0}], CFG)


# ------------------------------------------------------------ scenarios ----
def test_scenarios_shapes_and_sweeps():
    sc = scenarios.get("burst_storm", n_workloads=4, minutes=180, seed=1)
    assert sc.rates.shape == (4, 180)
    assert (sc.rates >= 0).all()

    swept = scenarios.startup_sweep(values=(10, 60), base="idle_wake",
                                    n_workloads=2, minutes=60)
    assert [s.cfg.startup_sec for s in swept] == [10, 60]
    np.testing.assert_array_equal(swept[0].rates, swept[1].rates)

    for name in scenarios.available():
        s = scenarios.get(name, n_workloads=2, minutes=60)
        assert s.rates.shape[0] == 2 and s.rates.shape[1] == 60


def test_archetype_pure_scenario_is_pure():
    sc = scenarios.get("archetype_pure", kind="SPIKE", n_workloads=3,
                       minutes=1440, seed=2)
    assert sc.meta["kind"] == "SPIKE"
    # spike family: heavy-tailed — the day's peak dwarfs the mean floor
    assert sc.rates.max() > 20 * max(sc.rates.mean(), 1.0)


# -------------------------------------------------- cooldown semantics ----
def test_apply_decision_cooldown_blocks_scale_down():
    lim = api.limiter_init()
    t, f = jnp.bool_(True), jnp.float32
    # scale up immediately
    lim, act = api.apply_decision(lim, f(2.0), f(5.0), f(300.0), t)
    assert float(act.add) == 3.0 and float(act.remove) == 0.0
    # scale down starts the cooldown
    lim, act = api.apply_decision(lim, f(5.0), f(2.0), f(300.0), t)
    assert float(act.remove) == 3.0 and float(lim.cooldown) == 300.0
    assert float(act.oscillation) == 1.0  # up then down
    # further scale-down blocked while cooling
    lim, act = api.apply_decision(lim, f(2.0), f(1.0), f(300.0), t)
    assert float(act.remove) == 0.0
    # ...but scale-up is never blocked
    lim, act = api.apply_decision(lim, f(2.0), f(6.0), f(300.0), t)
    assert float(act.add) == 4.0


# ------------------------------------------------------ adapter parity ----
@pytest.fixture(scope="module")
def engine_parts():
    import jax as _jax
    from repro.configs import get_config, smoke_config
    from repro.models import model as Mo
    cfg = smoke_config(get_config("internlm2_1_8b"))
    params = Mo.init(_jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_adapter_matches_sim_steady_state(engine_parts):
    """Constant-rate trace: the engine driven through the adapter and the
    cluster sim driven by the same hpa controller + SimConfig converge to
    the same replica count."""
    from repro.scaling import adapter
    from repro.serve.engine import Request, ServingEngine

    model_cfg, params = engine_parts
    minute_s = 1.0
    steps_per_min = 20
    eng = ServingEngine(model_cfg, params, lanes_per_replica=2,
                        max_replicas=8, step_time_s=minute_s / steps_per_min,
                        startup_s=0.1, slo_s=5.0)
    # fixed gen_len=4 -> 4 steps x 0.05 s = 0.2 engine-s service time
    sim_cfg = adapter.sim_config_for_engine(eng, minute_s=minute_s,
                                            service_s=0.2)
    # short stabilization so both backends settle within the trace
    ctrl = registry.get_controller("hpa", sim_cfg, stabilization_min=2.0,
                                   cooldown_min=2.0)
    auto = adapter.EngineAutoscaler(eng, ctrl, sim_cfg, minute_s=minute_s)

    per_min = 30                      # arrivals per logical minute
    minutes = 20
    rid = 0
    rng = np.random.default_rng(0)
    for _ in range(minutes):
        for s in range(steps_per_min):
            for _ in range(per_min // steps_per_min
                           + (rng.random() < (per_min % steps_per_min)
                              / steps_per_min)):
                eng.submit(Request(rid, eng.t, prompt_len=2, gen_len=4))
                rid += 1
            eng.step()
            auto.on_tick()

    out = simulate(jnp.full((minutes,), float(per_min)), ctrl, sim_cfg)
    sim_final = float(out.ready_mean[-1])
    eng_final = float(eng.ready_replicas)
    # ceil-based HPA has adjacent stable fixed points; both backends must
    # land in the same band (within one replica)
    assert abs(sim_final - eng_final) <= 1.0 + 1e-3, (sim_final, eng_final)
    assert eng.stats.served > 0


def test_scale_to_zero_agrees_across_backends():
    """Idle trace: sim-side controllers go to zero; the shared policy
    decides 0 for the adapter-style Obs too."""
    rates = jnp.zeros(180, jnp.float32)
    out = simulate(rates, registry.get_controller("hpa", CFG), CFG)
    assert float(out.ready_mean[-1]) == pytest.approx(0.0, abs=1e-6)

    ctrl = registry.get_controller("kpa", CFG)
    state = ctrl.init()
    idle_obs = api.Obs(ready_total=jnp.float32(1.0),
                       ready=jnp.float32(1.0),
                       util_ema=jnp.float32(0.0), queue=jnp.float32(0.0),
                       rate_rps=jnp.float32(0.0),
                       rate_history=jnp.zeros(60, jnp.float32),
                       minute_idx=jnp.int32(30))
    for _ in range(40):               # drain the stable window EMA
        state, desired, _ = ctrl.decide(state, idle_obs)
    assert float(desired) == 0.0


def test_metrics_on_batched_output():
    rates = _rates((2, 60), lam=600, seed=3)
    ctrls = [registry.get_controller(n, CFG) for n in ("hpa", "kpa")]
    out = batch.batch_simulate(ctrls, rates, CFG)
    agg = M.aggregate(jax.tree.map(lambda a: a[0], out),
                      workload_axis=True)
    assert 0.0 <= agg.slo_violation_rate <= 1.0
    assert agg.replica_minutes > 0
