"""The unified scaling control plane: registry round-trips, batched
policies x workloads parity with the per-policy simulators, hyperparam
grid stacking, scenarios, shared cooldown semantics, and sim-vs-engine
adapter parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.scaling import api, batch, registry, scenarios
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig, make_simulator, simulate

CFG = SimConfig()


def _rates(shape, lam=1200, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).poisson(lam, shape).astype(np.float32))


# ------------------------------------------------------------- registry ----
def test_registry_round_trips_every_policy():
    rates = _rates(90, lam=900)
    for name in registry.available():
        ctrl = registry.get_controller(name, CFG)
        assert ctrl.name == name or name in ctrl.name
        out = simulate(rates, ctrl, CFG)
        assert np.isfinite(np.asarray(out.served)).all()
        assert float(out.served.sum()) > 0


def test_registry_rejects_unknown_policy_and_hyperparam():
    with pytest.raises(KeyError):
        registry.get_controller("nope", CFG)
    with pytest.raises(TypeError):
        registry.get_controller("hpa", CFG, warp_factor=9)


def test_registry_overrides_apply():
    lo = registry.get_controller("hpa", CFG, target=0.3)
    hi = registry.get_controller("hpa", CFG, target=0.95)
    rates = _rates(120, lam=6000)
    rep_lo = float(simulate(rates, lo, CFG).replica_seconds.sum())
    rep_hi = float(simulate(rates, hi, CFG).replica_seconds.sum())
    assert rep_lo > rep_hi  # lower CPU target -> more replicas


def test_backcompat_reexports():
    from repro.core.controllers import hpa_controller as old_hpa
    from repro.scaling.policies import hpa_controller as new_hpa
    from repro.sim.cluster import Controller, Obs
    assert old_hpa is new_hpa
    assert Controller is api.Controller and Obs is api.Obs


# ---------------------------------------------------------------- batch ----
def test_batch_simulate_matches_per_policy_simulators():
    """The single compiled policies x workloads scan reproduces each
    standalone make_simulator run (same seeds, allclose)."""
    rates = _rates((3, 120), lam=1500, seed=1)
    names = registry.available()
    ctrls = [registry.get_controller(n, CFG) for n in names]
    out = batch.batch_simulate(ctrls, rates, CFG)       # [P, W, M]
    assert out.served.shape == (len(ctrls), 3, 120)
    for i, ctrl in enumerate(ctrls):
        single = make_simulator(ctrl, CFG)(rates)
        for field in ("served", "violated", "cold_starts",
                      "replica_seconds", "ready_mean", "oscillations"):
            np.testing.assert_allclose(
                np.asarray(getattr(out, field)[i]),
                np.asarray(getattr(single, field)), rtol=1e-5, atol=1e-5,
                err_msg=f"{ctrl.name}.{field}")


def test_grid_simulator_matches_individual_factories():
    grid = [{"target": 0.5}, {"target": 0.7}, {"target": 0.9}]
    rates = _rates((2, 90), lam=2400, seed=2)
    out = batch.make_grid_simulator("hpa", grid, CFG)(rates)
    assert out.served.shape == (3, 2, 90)
    for i, g in enumerate(grid):
        single = make_simulator(
            registry.get_controller("hpa", CFG, **g), CFG)(rates)
        np.testing.assert_allclose(np.asarray(out.served[i]),
                                   np.asarray(single.served), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(out.ready_mean[i]),
                                   np.asarray(single.ready_mean),
                                   rtol=1e-5)


def test_grid_simulator_sweeps_static_keys():
    """Non-stackable keys (here stabilization_min) are swept via static
    grouping: one compile per distinct static value, grid-order results
    that match the per-candidate factories."""
    grid = [{"target": t, "stabilization_min": s}
            for s in (2.0, 8.0) for t in (0.5, 0.8)]
    rates = _rates((2, 90), lam=2400, seed=3)
    run = batch.make_grid_simulator("hpa", grid, CFG)
    out = run(rates)
    assert out.served.shape == (4, 2, 90)
    assert run._cache_size() == 2        # one compile per static group
    for i, g in enumerate(grid):
        single = make_simulator(
            registry.get_controller("hpa", CFG, **g), CFG)(rates)
        np.testing.assert_allclose(np.asarray(out.served[i]),
                                   np.asarray(single.served), rtol=1e-5,
                                   err_msg=f"grid[{i}]={g}")


def test_grid_simulator_validates_keys_up_front():
    """Typo'd grid keys and fixed kwargs fail eagerly with the accepted
    hyperparameter list, not at trace time inside the factory."""
    with pytest.raises(TypeError, match=r"cooldwon_min.*accepts"):
        batch.make_grid_simulator("hpa", [{"target": 0.5}], CFG,
                                  cooldwon_min=2.0)
    with pytest.raises(TypeError, match=r"grid keys.*accepts"):
        batch.make_grid_simulator("hpa", [{"tarket": 0.5}], CFG)
    with pytest.raises(TypeError, match="also passed as fixed"):
        batch.make_grid_simulator("hpa", [{"target": 0.5}], CFG,
                                  target=0.7)


# ------------------------------------------------------------ scenarios ----
def test_scenarios_shapes_and_sweeps():
    sc = scenarios.get("burst_storm", n_workloads=4, minutes=180, seed=1)
    assert sc.rates.shape == (4, 180)
    assert (sc.rates >= 0).all()

    swept = scenarios.startup_sweep(values=(10, 60), base="idle_wake",
                                    n_workloads=2, minutes=60)
    assert [s.cfg.startup_sec for s in swept] == [10, 60]
    np.testing.assert_array_equal(swept[0].rates, swept[1].rates)

    for name in scenarios.available():
        s = scenarios.get(name, n_workloads=2, minutes=60)
        assert s.rates.shape[0] == 2 and s.rates.shape[1] == 60


def test_rps_per_replica_sweep_varies_only_the_plant():
    swept = scenarios.rps_per_replica_sweep(values=(5.0, 40.0),
                                            base="archetype_mix",
                                            n_workloads=2, minutes=60)
    assert [s.cfg.rps_per_replica for s in swept] == [5.0, 40.0]
    assert [s.meta["rps_per_replica"] for s in swept] == [5.0, 40.0]
    np.testing.assert_array_equal(swept[0].rates, swept[1].rates)
    # smaller per-replica capacity must need at least as many replicas
    ctrl = lambda cfg: registry.get_controller("hpa", cfg)
    rep = [float(simulate(jnp.asarray(s.rates[0]), ctrl(s.cfg),
                          s.cfg).replica_seconds.sum()) for s in swept]
    assert rep[0] >= rep[1]


def test_startup_sweep_shifts_cold_starts():
    swept = scenarios.startup_sweep(values=(5, 120), base="idle_wake",
                                    n_workloads=1, minutes=120, seed=5)
    cold = []
    for s in swept:
        out = simulate(jnp.asarray(s.rates[0]),
                       registry.get_controller("hpa", s.cfg), s.cfg)
        cold.append(float(out.cold_starts.sum()))
    # slower pod startup can only make wake-from-zero cold starts worse
    assert cold[1] >= cold[0]


def test_archetype_pure_scenario_is_pure():
    sc = scenarios.get("archetype_pure", kind="SPIKE", n_workloads=3,
                       minutes=1440, seed=2)
    assert sc.meta["kind"] == "SPIKE"
    # spike family: heavy-tailed — the day's peak dwarfs the mean floor
    assert sc.rates.max() > 20 * max(sc.rates.mean(), 1.0)


# -------------------------------------------------- cooldown semantics ----
def test_apply_decision_cooldown_blocks_scale_down():
    lim = api.limiter_init()
    t, f = jnp.bool_(True), jnp.float32
    # scale up immediately
    lim, act = api.apply_decision(lim, f(2.0), f(5.0), f(300.0), t)
    assert float(act.add) == 3.0 and float(act.remove) == 0.0
    # scale down starts the cooldown
    lim, act = api.apply_decision(lim, f(5.0), f(2.0), f(300.0), t)
    assert float(act.remove) == 3.0 and float(lim.cooldown) == 300.0
    assert float(act.oscillation) == 1.0  # up then down
    # further scale-down blocked while cooling
    lim, act = api.apply_decision(lim, f(2.0), f(1.0), f(300.0), t)
    assert float(act.remove) == 0.0
    # ...but scale-up is never blocked
    lim, act = api.apply_decision(lim, f(2.0), f(6.0), f(300.0), t)
    assert float(act.add) == 4.0


# ------------------------------------------------------ adapter parity ----
@pytest.fixture(scope="module")
def engine_parts():
    import jax as _jax
    from repro.configs import get_config, smoke_config
    from repro.models import model as Mo
    cfg = smoke_config(get_config("internlm2_1_8b"))
    params = Mo.init(_jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.slow
def test_adapter_matches_sim_steady_state(engine_parts):
    """Constant-rate trace: the engine driven through the adapter and the
    cluster sim driven by the same hpa controller + SimConfig converge to
    the same replica count."""
    from repro.scaling import adapter
    from repro.serve.engine import Request, ServingEngine

    model_cfg, params = engine_parts
    minute_s = 1.0
    steps_per_min = 20
    eng = ServingEngine(model_cfg, params, lanes_per_replica=2,
                        max_replicas=8, step_time_s=minute_s / steps_per_min,
                        startup_s=0.1, slo_s=5.0)
    # fixed gen_len=4 -> 4 steps x 0.05 s = 0.2 engine-s service time
    sim_cfg = adapter.sim_config_for_engine(eng, minute_s=minute_s,
                                            service_s=0.2)
    # short stabilization so both backends settle within the trace
    ctrl = registry.get_controller("hpa", sim_cfg, stabilization_min=2.0,
                                   cooldown_min=2.0)
    auto = adapter.EngineAutoscaler(eng, ctrl, sim_cfg, minute_s=minute_s)

    per_min = 30                      # arrivals per logical minute
    minutes = 20
    rid = 0
    rng = np.random.default_rng(0)
    for _ in range(minutes):
        for s in range(steps_per_min):
            for _ in range(per_min // steps_per_min
                           + (rng.random() < (per_min % steps_per_min)
                              / steps_per_min)):
                eng.submit(Request(rid, eng.t, prompt_len=2, gen_len=4))
                rid += 1
            eng.step()
            auto.on_tick()

    out = simulate(jnp.full((minutes,), float(per_min)), ctrl, sim_cfg)
    sim_final = float(out.ready_mean[-1])
    eng_final = float(eng.ready_replicas)
    # ceil-based HPA has adjacent stable fixed points; both backends must
    # land in the same band (within one replica)
    assert abs(sim_final - eng_final) <= 1.0 + 1e-3, (sim_final, eng_final)
    assert eng.stats.served > 0


def test_scale_to_zero_agrees_across_backends():
    """Idle trace: sim-side controllers go to zero; the shared policy
    decides 0 for the adapter-style Obs too."""
    rates = jnp.zeros(180, jnp.float32)
    out = simulate(rates, registry.get_controller("hpa", CFG), CFG)
    assert float(out.ready_mean[-1]) == pytest.approx(0.0, abs=1e-6)

    ctrl = registry.get_controller("kpa", CFG)
    state = ctrl.init()
    idle_obs = api.Obs(ready_total=jnp.float32(1.0),
                       ready=jnp.float32(1.0),
                       util_ema=jnp.float32(0.0), queue=jnp.float32(0.0),
                       rate_rps=jnp.float32(0.0),
                       rate_history=jnp.zeros(60, jnp.float32),
                       minute_idx=jnp.int32(30))
    for _ in range(40):               # drain the stable window EMA
        state, desired, _ = ctrl.decide(state, idle_obs)
    assert float(desired) == 0.0


class FakeEngine:
    """Duck-typed stand-in for ServingEngine: just the attributes the
    adapter senses and the `scale_to` actuator, with manual time."""

    def __init__(self, *, ready=2, lanes=2, startup_s=6.0, slo_s=1.0,
                 max_replicas=10):
        self.ready_replicas = ready
        self.lanes = lanes
        self.startup_s = startup_s
        self.slo_s = slo_s
        self.max_replicas = max_replicas
        self.starting, self.active, self.queue = [], [], []
        self.t = 0.0
        self.arrivals_total = 0
        self.rate = 0.0
        self.scale_calls = []

    def observed_rate(self, window_s):
        return self.rate

    def scale_to(self, n):
        self.scale_calls.append(n)
        self.ready_replicas = n


def test_sim_config_for_engine_converts_to_logical_units():
    from repro.scaling import adapter
    eng = FakeEngine(ready=3, lanes=4, startup_s=6.0, slo_s=1.0)
    # 1 logical minute = 2 engine-seconds -> 30 logical sec per engine sec
    cfg = adapter.sim_config_for_engine(eng, minute_s=2.0, service_s=0.4)
    assert cfg.startup_sec == 180            # 6 engine-s x 30
    assert cfg.service_sec == pytest.approx(0.4 * 30)
    assert cfg.slo_sec == pytest.approx(30.0)
    assert cfg.rps_per_replica == pytest.approx(4 / (0.4 * 30))
    assert cfg.initial_replicas == 3.0
    # identity mapping at minute_s=60
    cfg60 = adapter.sim_config_for_engine(eng, minute_s=60.0, service_s=0.4)
    assert cfg60.startup_sec == 6 and cfg60.service_sec == pytest.approx(0.4)


def test_adapter_cooldown_blocks_scale_down_in_logical_time():
    """A decide() cooldown is logical seconds; with minute_s=2 the
    adapter must hold a second scale-down for cooldown/30 engine-seconds."""
    import jax.numpy as jnp
    from repro.scaling import adapter

    def shrinker(cfg):
        def init():
            return jnp.float32(0.0)
        def on_minute(state, hist, minute_idx):
            return state
        def decide(state, obs):
            return state, obs.ready_total - 1.0, jnp.float32(120.0)
        return api.Controller("shrinker", init, on_minute, decide)

    eng = FakeEngine(ready=8)
    minute_s = 2.0
    cfg = adapter.sim_config_for_engine(eng, minute_s=minute_s,
                                        control_interval_sec=15)
    auto = adapter.EngineAutoscaler(eng, shrinker(cfg), cfg,
                                    minute_s=minute_s)
    # control fires every 15 logical s = 0.5 engine s
    for step in range(1, 9):
        eng.t = step * 0.25
        auto.on_tick()
    # first decision scales 8 -> 7 and starts a 120-logical-s cooldown
    # (= 4 engine s); every later decision within that window is blocked
    assert eng.scale_calls[0] == 7
    assert all(c == 7 for c in eng.scale_calls), eng.scale_calls
    assert auto.last_cooldown_s == pytest.approx(120.0)
    # past the cooldown (4 engine-s later) the next shrink goes through
    # (the clock drains on the first post-expiry decision, which unblocks
    # the one after — the same pre-decay check the simulator compiles)
    eng.t = 4.0 + 0.5
    auto.on_tick()
    eng.t = 5.0
    auto.on_tick()
    assert eng.scale_calls[-1] == 6


def test_adapter_scales_to_zero_on_idle_engine():
    from repro.scaling import adapter
    eng = FakeEngine(ready=2)
    auto = adapter.EngineAutoscaler.from_policy(
        eng, "hpa", minute_s=1.0, cooldown_min=0.0)
    # idle engine: no traffic, empty queue; util EMA decays to ~0
    for step in range(1, 80):
        eng.t = step * 0.25
        auto.on_tick()
    assert eng.scale_calls[-1] == 0
    assert eng.ready_replicas == 0


def test_adapter_from_policy_resolves_forecaster():
    from repro.scaling import adapter
    eng = FakeEngine(ready=2)
    auto = adapter.EngineAutoscaler.from_policy(eng, "predictive",
                                                forecaster="ewma",
                                                minute_s=1.0)
    eng.rate = 5.0
    eng.t = 0.25
    auto.on_tick()
    assert auto.last_desired >= 1.0


def test_metrics_on_batched_output():
    rates = _rates((2, 60), lam=600, seed=3)
    ctrls = [registry.get_controller(n, CFG) for n in ("hpa", "kpa")]
    out = batch.batch_simulate(ctrls, rates, CFG)
    agg = M.aggregate(jax.tree.map(lambda a: a[0], out),
                      workload_axis=True)
    assert 0.0 <= agg.slo_violation_rate <= 1.0
    assert agg.replica_minutes > 0
