import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself). Tests that
# need a multi-device mesh spawn a subprocess with XLA_FLAGS set.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # Tier-1 CI runs `-m "not slow"`; the nightly job runs everything.
    config.addinivalue_line(
        "markers",
        "slow: heavyweight model/train/system tests, run in the nightly "
        "full-suite CI job (tier-1 deselects them with -m 'not slow')")
