import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the dry-run sets 512 itself). Tests that
# need a multi-device mesh spawn a subprocess with XLA_FLAGS set.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # Tier-1 CI runs `-m "not slow"`; the nightly job runs everything.
    config.addinivalue_line(
        "markers",
        "slow: heavyweight model/train/system tests, run in the nightly "
        "full-suite CI job (tier-1 deselects them with -m 'not slow')")
    config.addinivalue_line(
        "markers",
        "requires_tpu: compiled-mode (interpret=False) kernel parity "
        "pins; auto-skipped unless jax.default_backend() == 'tpu'")


def pytest_collection_modifyitems(config, items):
    import pytest
    tpu_items = [it for it in items
                 if it.get_closest_marker("requires_tpu") is not None]
    if not tpu_items:
        return
    import jax
    if jax.default_backend() == "tpu":
        return
    skip = pytest.mark.skip(
        reason="requires a TPU backend (interpret=False kernel path)")
    for it in tpu_items:
        it.add_marker(skip)
