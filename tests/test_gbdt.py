"""JAX histogram-GBDT classifier."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gbdt


@pytest.fixture(scope="module")
def trained():
    rng = np.random.default_rng(0)
    N = 4000
    X = rng.normal(size=(N, 6)).astype(np.float32)
    y = ((X[:, 0] > 0).astype(int) * 2 + (X[:, 1] * X[:, 2] > 0)).astype(
        np.int32)  # 4 classes, nonlinear
    cfg = gbdt.GBDTConfig(n_rounds=30, depth=4)
    params = gbdt.fit(X, y, cfg)
    return X, y, params


def test_learns_nonlinear_4class(trained):
    X, y, params = trained
    acc = float((np.asarray(gbdt.predict(params, jnp.asarray(X))) == y
                 ).mean())
    assert acc > 0.93


def test_proba_normalized(trained):
    X, _, params = trained
    proba = np.asarray(gbdt.predict_proba(params, jnp.asarray(X[:100])))
    np.testing.assert_allclose(proba.sum(1), 1.0, rtol=1e-5)
    assert proba.min() >= 0.0


def test_save_load_roundtrip(tmp_path, trained):
    X, _, params = trained
    path = str(tmp_path / "model.npz")
    gbdt.save(params, path)
    loaded = gbdt.load(path)
    a = np.asarray(gbdt.predict_logits(params, jnp.asarray(X[:50])))
    b = np.asarray(gbdt.predict_logits(loaded, jnp.asarray(X[:50])))
    np.testing.assert_array_equal(a, b)


def test_class_weights_help_rare_class():
    rng = np.random.default_rng(1)
    N = 6000
    X = rng.normal(size=(N, 4)).astype(np.float32)
    y = np.zeros(N, np.int32)
    rare = rng.choice(N, size=60, replace=False)     # 1% rare class
    y[rare] = 1
    X[rare, 0] += 3.0
    cfg = gbdt.GBDTConfig(n_classes=2, n_rounds=20, class_weighted=True)
    params = gbdt.fit(X, y, cfg)
    pred = np.asarray(gbdt.predict(params, jnp.asarray(X)))
    recall = (pred[rare] == 1).mean()
    assert recall > 0.8


def test_binning_monotonic():
    X = np.linspace(0, 1, 1000)[:, None].astype(np.float32)
    edges = gbdt.compute_bin_edges(X, 64)
    b = np.asarray(gbdt.bin_features(jnp.asarray(X), jnp.asarray(edges)))
    assert (np.diff(b[:, 0]) >= 0).all()
    assert b.min() >= 0 and b.max() <= 63
