"""The `repro.forecast` subsystem: registry round-trips, forecaster
semantics, batched backtest parity, split-conformal coverage, the
confidence path into Algorithm 1, and the forecasters x policies x
workloads batched simulation (bit-exact vs the per-forecaster path)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import forecasting as fc
from repro.core import uncertainty
from repro.data.azure_synth import generate_traces
from repro.forecast import (Forecaster, backtest, conformal,
                            interval_confidence, registry)
from repro.forecast.api import FState
from repro.core.archetypes import Archetype


# ------------------------------------------------------------- registry ----
def test_registry_round_trips_every_forecaster():
    for name in registry.available():
        f = registry.make(name)
        assert isinstance(f, Forecaster) and f.name == name
        st = f.init()
        assert isinstance(st, FState)
        for v in (5.0, 9.0, 4.0, 12.0):
            st = f.update(st, jnp.float32(v))
        iv = f.forecast(st, 15)
        assert float(iv.lo) <= float(iv.point) <= float(iv.hi)
        assert float(iv.lo) >= 0.0


def test_registry_rejects_unknown_names_and_params():
    with pytest.raises(KeyError):
        registry.make("oracle")
    with pytest.raises(TypeError):
        registry.make("ewma", period=60)
    # instances pass through, but can't be re-parameterized
    f = registry.make("ewma")
    assert registry.make(f) is f
    with pytest.raises(TypeError):
        registry.make(f, alpha=0.5)


def test_archetype_defaults_cover_every_archetype():
    for arch in Archetype:
        name = registry.for_archetype(arch)
        assert name in registry.available()
    assert registry.for_archetype(Archetype.RAMP) == "linear_trend"
    assert registry.for_archetype(Archetype.PERIODIC) == "holt_winters"


# --------------------------------------------------- forecaster semantics ----
def test_linear_trend_forecaster_exact_on_line():
    f = registry.make("linear_trend", window=30)
    st = f.init()
    for v in 10.0 + 3.0 * np.arange(30):
        st = f.update(st, jnp.float32(v))
    iv = f.forecast(st, 10)
    # increasing line: peak over the horizon is the endpoint forecast
    assert float(iv.point) == pytest.approx(10.0 + 3.0 * 39, rel=1e-4)


def test_seasonal_naive_repeats_the_cycle():
    period = 12
    f = registry.make("seasonal_naive", period=period)
    st = f.init()
    cycle = 50.0 + 40.0 * np.sin(2 * np.pi * np.arange(period) / period)
    for _ in range(3):
        for v in cycle:
            st = f.update(st, jnp.float32(v))
    # peak over one full period = the cycle's max
    iv = f.forecast(st, period)
    assert float(iv.point) == pytest.approx(cycle.max(), rel=1e-5)


def test_ewma_converges_to_level_with_tight_band():
    f = registry.make("ewma", alpha=0.5)
    st = f.init()
    for _ in range(80):
        st = f.update(st, jnp.float32(42.0))
    iv = f.forecast(st, 15)
    assert float(iv.point) == pytest.approx(42.0, rel=1e-3)
    # constant input -> residual EWMA ~ 0 -> near-degenerate interval
    assert float(iv.hi - iv.lo) < 1.0
    assert float(interval_confidence(iv)) > 0.95


def test_native_interval_widens_with_noise_and_horizon():
    rng = np.random.default_rng(0)
    f = registry.make("ewma")
    st_lo, st_hi = f.init(), f.init()
    for _ in range(200):
        st_lo = f.update(st_lo, jnp.float32(100.0 + rng.normal(0, 1)))
        st_hi = f.update(st_hi, jnp.float32(100.0 + rng.normal(0, 25)))
    w = lambda iv: float(iv.hi - iv.lo)
    assert w(f.forecast(st_hi, 1)) > w(f.forecast(st_lo, 1))
    assert w(f.forecast(st_hi, 16)) > w(f.forecast(st_hi, 1))
    c_lo = float(interval_confidence(f.forecast(st_lo, 1)))
    c_hi = float(interval_confidence(f.forecast(st_hi, 1)))
    assert c_lo > c_hi  # noisier series -> lower forecast confidence


# ------------------------------------------------------ batched backtest ----
def test_batch_backtest_bit_exact_vs_per_forecaster():
    rng = np.random.default_rng(3)
    y = rng.gamma(2.0, 10.0, size=(5, 240)).astype(np.float32)
    names = registry.available()
    out = backtest.batch_smooth(names, y)              # [F, B, T]
    assert out.shape == (len(names), 5, 240)
    for i, name in enumerate(names):
        single = backtest.stream_smooth(name, y)
        np.testing.assert_array_equal(np.asarray(out[i]),
                                      np.asarray(single),
                                      err_msg=name)


def test_smooth_accepts_lists_and_1d_input():
    """`smooth` coerces before touching .shape, so Python lists and bare
    1-D traces work on every forecaster (including Holt-Winters' custom
    offline path, which had its own pre-coercion .shape read)."""
    trace = [3.0, 4.0, 5.0, 6.0, 5.0, 4.0] * 20
    for name in registry.available():
        f = registry.make(name)
        from_list = f.smooth(trace)
        from_arr = f.smooth(jnp.asarray(trace, jnp.float32))
        assert from_list.shape == (len(trace),), name
        np.testing.assert_array_equal(np.asarray(from_list),
                                      np.asarray(from_arr), err_msg=name)


def test_smooth_matches_stream_path_for_scan_forecasters():
    """Forecasters without a custom offline kernel path must have
    `smooth` == the streaming scan exactly."""
    rng = np.random.default_rng(4)
    y = rng.gamma(2.0, 10.0, size=(3, 180)).astype(np.float32)
    for name in ("ewma", "linear_trend", "seasonal_naive"):
        f = registry.make(name)
        np.testing.assert_array_equal(
            np.asarray(f.smooth(jnp.asarray(y))),
            np.asarray(backtest.stream_smooth(f, y)), err_msg=name)


def test_hw_smooth_dispatch_matches_kernel_oracle():
    """On CPU the HW forecaster's offline path is the hw_smooth oracle —
    the same function the Pallas kernel is validated against."""
    rng = np.random.default_rng(5)
    y = rng.gamma(2.0, 5.0, size=(4, 300)).astype(np.float32)
    f = registry.make("holt_winters", period=24)
    got = np.asarray(f.smooth(jnp.asarray(y)))
    want = np.asarray(fc.hw_smooth(jnp.asarray(y), period=24))
    np.testing.assert_array_equal(got, want)


def test_hw_smooth_reuses_one_compile_across_series_lengths():
    """Mixed-length backtests must not retrace per length: series pad to
    a 256 bucket, so 100/130/250 all share one compilation."""
    from repro.core.forecasting import _hw_smooth_padded
    rng = np.random.default_rng(6)
    outs = {}
    before = _hw_smooth_padded._cache_size()
    for T in (100, 130, 250):
        y = rng.gamma(2.0, 5.0, size=(2, T)).astype(np.float32)
        outs[T] = np.asarray(fc.hw_smooth(jnp.asarray(y), period=24))
        assert outs[T].shape == (2, T)
    grown = _hw_smooth_padded._cache_size() - before
    assert grown <= 1, f"retraced per length: {grown} new compilations"
    # padding must not change the causal prefix
    y = rng.gamma(2.0, 5.0, size=(2, 100)).astype(np.float32)
    direct = np.asarray(_hw_smooth_padded(
        jnp.asarray(np.pad(y, ((0, 0), (0, 156)))), jnp.float32(0.1),
        jnp.float32(0.01), jnp.float32(0.3), period=24))[:, :100]
    np.testing.assert_array_equal(
        np.asarray(fc.hw_smooth(jnp.asarray(y), period=24)), direct)


# -------------------------------------------------------------- conformal ----
@pytest.fixture(scope="module")
def stationary_traces():
    traces = generate_traces(n_functions=12, n_days=1, seed=99,
                             mix={Archetype.STATIONARY_NOISY: 1.0})
    return traces.counts          # [12, 1440]


@pytest.mark.parametrize("alpha", [0.8, 0.9, 0.95])
def test_conformal_coverage_near_nominal(stationary_traces, alpha):
    """Split-conformal bands hit their nominal coverage within +-5 pts
    on held-out halves of stationary Azure-like traces."""
    f = registry.make("ewma")
    calib = stationary_traces[:, :720]
    test = stationary_traces[:, 720:]
    band = conformal.calibrate(f, calib, alpha=alpha)
    cov = conformal.coverage(f, band, test)
    assert abs(cov - alpha) <= 0.05, (cov, alpha)


def test_conformal_band_feeds_interval_and_confidence(stationary_traces):
    f = registry.make("ewma")
    lo = conformal.calibrate(f, stationary_traces, alpha=0.5)
    hi = conformal.calibrate(f, stationary_traces, alpha=0.95)
    assert float(hi.q) > float(lo.q)          # wider band at higher alpha
    # lower alpha -> narrower band -> higher confidence
    assert float(conformal.confidence(lo)) > float(conformal.confidence(hi))

    wrapped = conformal.wrap(f, hi)
    st = wrapped.init()
    for v in stationary_traces[0, :120]:
        st = wrapped.update(st, jnp.float32(v))
    iv1 = wrapped.forecast(st, 1)
    iv9 = wrapped.forecast(st, 9)
    assert float(iv1.hi - iv1.point) == pytest.approx(float(hi.q), rel=1e-5)
    # sqrt-horizon widening: 9 steps -> 3x the one-step half-width
    assert float(iv9.hi - iv9.point) == pytest.approx(3 * float(hi.q),
                                                      rel=1e-5)


def test_margin_multiplier_monotone_under_decreasing_confidence():
    cs = jnp.linspace(1.0, 0.0, 21)
    ms = np.asarray(uncertainty.margin_multiplier(cs))
    assert (np.diff(ms) >= -1e-7).all()       # conf down -> margin up
    assert ms[0] == pytest.approx(1.0) and ms[-1] == pytest.approx(1.5)


def test_interval_confidence_monotone_in_width():
    from repro.forecast.api import Interval
    point = jnp.float32(100.0)
    widths = [0.0, 10.0, 50.0, 200.0]
    cs = [float(interval_confidence(
        Interval(point, point - w / 2, point + w / 2))) for w in widths]
    assert cs[0] == pytest.approx(1.0)
    assert all(a > b for a, b in zip(cs, cs[1:]))
    assert all(0.0 <= c <= 1.0 for c in cs)


def test_interval_confidence_idle_trace_stays_high():
    """An idle/near-zero trace must not collapse confidence: with the
    scale floored at MIN_CONF_SCALE (1 req/min), a tight band around a
    ~0 point forecast reads as near-certain, not maximally uncertain."""
    from repro.forecast.api import Interval, MIN_CONF_SCALE
    f = registry.make("ewma")
    st = f.init()
    for _ in range(60):                 # a workload that is simply idle
        st = f.update(st, jnp.float32(0.0))
    iv = f.forecast(st, 15)
    assert float(iv.point) == pytest.approx(0.0, abs=1e-6)
    assert float(interval_confidence(iv)) > 0.95
    # exact floor semantics: c = floor / (floor + width)
    zero = Interval(jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.5))
    assert float(interval_confidence(zero)) == pytest.approx(
        MIN_CONF_SCALE / (MIN_CONF_SCALE + 0.5), rel=1e-6)
    # a caller-tracked scale still tightens the floor
    assert float(interval_confidence(zero, scale=jnp.float32(10.0))) \
        == pytest.approx(10.0 / 10.5, rel=1e-6)


# --------------------------------------- wired into the control plane ----
def test_aapa_scales_with_named_forecaster_and_conformal_confidence(
        stationary_traces):
    """Acceptance: registry.make("aapa") runs end-to-end with a named
    forecaster + conformal band, and the band's width actually modulates
    Algorithm 1 (conf = classifier x interval signal)."""
    from repro.scaling import registry as scaling_registry
    from repro.sim.cluster import SimConfig, simulate

    cfg = SimConfig()
    f = registry.make("ewma")
    band = conformal.calibrate(f, stationary_traces[:, :720], alpha=0.9)
    ctrl = scaling_registry.make("aapa", cfg, forecaster="ewma", band=band)
    out = simulate(jnp.asarray(stationary_traces[0]), ctrl, cfg)
    assert float(out.served.sum()) > 0

    # eager wiring check: drive on_minute to a reclassify boundary
    ctrl_plain = scaling_registry.make("aapa", cfg, forecaster="ewma",
                                       forecast_confidence=False)
    hist = jnp.asarray(stationary_traces[0, :60])
    st_band = ctrl.init()
    st_plain = ctrl_plain.init()
    for m in range(1, 21):
        st_band = ctrl.on_minute(st_band, hist, jnp.int32(m))
        st_plain = ctrl_plain.on_minute(st_plain, hist, jnp.int32(m))
    # default classifier confidence is 0.5; the conformal path multiplies
    # by the interval signal in (0, 1), the plain path does not
    assert float(st_plain.conf) == pytest.approx(0.5)
    assert 0.0 < float(st_band.conf) < 0.5
    expected = 0.5 * float(interval_confidence(
        conformal.wrap(f, band).forecast(st_band.fc, 15), band.scale))
    assert float(st_band.conf) == pytest.approx(expected, rel=1e-5)


def test_forecast_batch_simulator_bit_exact():
    """Acceptance: forecasters x policies x workloads in one jitted scan,
    bit-exact against each per-forecaster standalone simulation."""
    from repro.scaling import batch, registry as scaling_registry
    from repro.sim.cluster import SimConfig, make_simulator

    cfg = SimConfig()
    rng = np.random.default_rng(7)
    rates = jnp.asarray(rng.poisson(900, (2, 75)).astype(np.float32))
    fore = ("holt_winters", "ewma", "linear_trend")
    pols = ("predictive", "aapa")
    out = batch.make_forecast_batch_simulator(pols, fore, cfg)(rates)
    assert out.served.shape == (3, 2, 2, 75)
    for fi, f in enumerate(fore):
        for pi, p in enumerate(pols):
            single = make_simulator(
                scaling_registry.make(p, cfg, forecaster=f), cfg)(rates)
            for field in ("served", "violated", "replica_seconds",
                          "ready_mean"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(out, field)[fi, pi]),
                    np.asarray(getattr(single, field)),
                    err_msg=f"{f}/{p}.{field}")


def test_forecast_batch_simulator_rejects_forecasterless_policy():
    from repro.scaling import batch
    with pytest.raises(TypeError):
        batch.make_forecast_batch_simulator(("hpa",), ("ewma",))
