"""Serving engine behaviour (continuous batching + scaling control)."""
import jax
import pytest

from repro.configs import get_config, smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine



# Heavyweight model/train/system tier: nightly CI runs these; tier-1 deselects
# with -m 'not slow'.
pytestmark = pytest.mark.slow

@pytest.fixture(scope="module")
def engine_parts():
    cfg = smoke_config(get_config("internlm2_1_8b"))
    params = M.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk(cfg, params, **kw):
    defaults = dict(lanes_per_replica=2, max_replicas=4,
                    step_time_s=0.05, startup_s=0.2, slo_s=1.0)
    defaults.update(kw)
    return ServingEngine(cfg, params, **defaults)


def test_requests_complete(engine_parts):
    cfg, params = engine_parts
    eng = _mk(cfg, params)
    for i in range(6):
        eng.submit(Request(i, 0.0, prompt_len=2, gen_len=3))
    for _ in range(40):
        eng.step()
    s = eng.summary()
    assert s["served"] == 6
    assert s["queue_len"] == 0
    assert s["p95_ms"] > 0


def test_scale_up_respects_startup_delay(engine_parts):
    cfg, params = engine_parts
    eng = _mk(cfg, params, startup_s=0.5)
    eng.scale_to(3)
    assert eng.ready_replicas == 1 and len(eng.starting) == 2
    for _ in range(4):       # 0.2 s < startup
        eng.step()
    assert eng.ready_replicas == 1
    for _ in range(10):      # past startup
        eng.step()
    assert eng.ready_replicas == 3


def test_scale_down_cancels_starting_first(engine_parts):
    cfg, params = engine_parts
    eng = _mk(cfg, params, startup_s=10.0)
    eng.scale_to(4)
    assert len(eng.starting) == 3
    eng.scale_to(2)
    assert len(eng.starting) == 1 and eng.ready_replicas == 1


def test_observed_rate_uses_sliding_window(engine_parts):
    cfg, params = engine_parts
    eng = _mk(cfg, params)
    for i in range(8):
        eng.submit(Request(i, eng.t, prompt_len=2, gen_len=2))
    for _ in range(20):               # advance to t = 1.0 s
        eng.step()
    # all 8 arrivals sit at t=0: outside a 0.5 s window, inside a 2 s
    # one — in either query order (non-destructive windowing)
    assert eng.observed_rate(window_s=0.5) == 0.0
    assert eng.observed_rate(window_s=2.0) == pytest.approx(8.0)


def test_scale_to_zero_and_activator_cold_start(engine_parts):
    cfg, params = engine_parts
    eng = _mk(cfg, params, startup_s=0.1)
    eng.scale_to(0)
    assert eng.ready_replicas == 0 and not eng.starting
    # arrivals during zero-ready each count as a cold start, and the
    # activator wakes exactly one replica
    for i in range(3):
        eng.submit(Request(i, eng.t, prompt_len=2, gen_len=2))
    assert eng.stats.cold_starts == 3
    assert len(eng.starting) == 1
    for _ in range(20):
        eng.step()
    assert eng.ready_replicas == 1
    assert eng.summary()["served"] == 3


def test_more_replicas_more_throughput(engine_parts):
    cfg, params = engine_parts
    done = {}
    for n in (1, 4):
        eng = _mk(cfg, params, startup_s=0.0)
        eng.scale_to(n)
        eng.step()
        for i in range(16):
            eng.submit(Request(i, 0.0, prompt_len=2, gen_len=4))
        for _ in range(10):
            eng.step()
        done[n] = eng.summary()["served"]
    assert done[4] > done[1]
