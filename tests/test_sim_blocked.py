"""Control-period-blocked scan vs the retained seed tick-level scan.

The blocked fast path (`simulate`) runs `controller.decide` once per
control interval; the reference path (`simulate_reference`) keeps the
seed semantics — decide evaluated on every one-second tick and masked
off-interval. The two are BIT-EXACT by construction: the masked decides
were fully discarded and every masked action is an exact float identity
(see the sim.cluster module docstring). That claim is about the float
*semantics* — same operations in the same order — and is pinned here by
`test_bit_exact_semantics`, which compares op-for-op under
`jax.disable_jit()` for every registry policy, including a
control interval that does not divide 60 (remainder-block semantics:
the last block simply runs the leftover ``60 % ci`` ticks).

The compiled programs are additionally pinned tightly (rtol 2e-6) over
policies x scenarios x control intervals. Compiled comparisons cannot be
bitwise in general: XLA/LLVM may FMA-contract a mul+add chain inside a
policy's `decide` in one program embedding and not the other, which on
chaotic inputs (burst_storm's 1e5-scale spikes) occasionally moves a
`ceil` by one. The plant math itself is written contraction-stable (see
`_flow_tick`), so in like-for-like embeddings the compiled paths agree
bitwise too — but only the eager pin is a structural guarantee."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.scaling import registry, scenarios
from repro.scaling.api import Controller
from repro.sim import cluster as SC
from repro.sim.cluster import SimConfig, simulate, simulate_reference

W, MINUTES = 2, 45
SCENARIOS = ("burst_storm", "idle_wake", "archetype_mix")

_SIM_CACHE: dict = {}


def _batched(ci: int):
    """One jitted blocked + one jitted reference batch over every
    registry policy, cached per control interval so the scenario sweep
    reuses the compiles (all scenarios share the [W, MINUTES] shape)."""
    if ci not in _SIM_CACHE:
        cfg = SimConfig(control_interval_sec=ci)
        ctrls = [registry.get_controller(n, cfg)
                 for n in registry.available()]

        def stack(sim_fn):
            def run(rates):
                outs = [jax.vmap(lambda r, c=c: sim_fn(r, c, cfg))(rates)
                        for c in ctrls]
                return jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
            return jax.jit(run)

        _SIM_CACHE[ci] = (stack(simulate), stack(simulate_reference))
    return _SIM_CACHE[ci]


def _assert_bit_exact(blocked, reference, ctx):
    for field in blocked._fields:
        b = np.asarray(getattr(blocked, field))
        r = np.asarray(getattr(reference, field))
        np.testing.assert_array_equal(b, r, err_msg=f"{ctx}.{field}")


def _assert_ulp_tight(blocked, reference, ctx):
    for field in blocked._fields:
        b = np.asarray(getattr(blocked, field))
        r = np.asarray(getattr(reference, field))
        np.testing.assert_allclose(b, r, rtol=2e-6, atol=2e-4,
                                   err_msg=f"{ctx}.{field}")


def test_bit_exact_semantics():
    """THE parity pin: op-for-op (eager) the blocked scan reproduces the
    seed tick-level scan bit-for-bit, every registry policy, at ci=7
    (8 full blocks + a 4-tick remainder block per minute)."""
    cfg = SimConfig(control_interval_sec=7)
    rng = np.random.default_rng(3)
    rates = jnp.asarray(rng.poisson(2000, 2).astype(np.float32))
    with jax.disable_jit():
        for name in registry.available():
            ctrl = registry.get_controller(name, cfg)
            _assert_bit_exact(simulate(rates, ctrl, cfg),
                              simulate_reference(rates, ctrl, cfg),
                              f"eager ci=7 {name}")


@pytest.mark.parametrize("ci", (15, 7))
@pytest.mark.parametrize("scenario", SCENARIOS)
def test_blocked_matches_reference_all_policies(ci, scenario):
    """Compiled: every registry policy at the default 15 s interval and
    at 7 s (60 % 7 != 0: exercises the remainder block)."""
    sc = scenarios.get(scenario, n_workloads=W, minutes=MINUTES, seed=3)
    rates = jnp.asarray(sc.rates, jnp.float32)
    blocked_fn, ref_fn = _batched(ci)
    _assert_ulp_tight(blocked_fn(rates), ref_fn(rates),
                      f"ci={ci} {scenario}[all-policies]")


@pytest.mark.parametrize("ci", (1, 13, 20, 45, 60, 90))
def test_blocked_matches_reference_interval_sweep(ci):
    """Interval sweep on two policies covering both plant-block regimes:
    ci=1 (every tick a head), non-divisors 13/20/45, ci=60 (one decide a
    minute, 59-tick scan block), ci=90 (> 60: clamped, still one head)."""
    cfg = SimConfig(control_interval_sec=ci)
    rng = np.random.default_rng(11)
    rates = jnp.asarray(rng.poisson(1500, 40).astype(np.float32))
    for name in ("hpa", "kpa"):
        ctrl = registry.get_controller(name, cfg)
        _assert_ulp_tight(simulate(rates, ctrl, cfg),
                          simulate_reference(rates, ctrl, cfg),
                          f"ci={ci} {name}")


def test_remainder_block_head_schedule():
    """ci=7 must place decides at sec 0,7,...,56 within each minute —
    exactly where the reference's `sec % ci == 0` mask is true. A
    controller whose every applied decide is a scaling action sees
    ceil(60/7)=9 actions per minute on both paths."""
    cfg = SimConfig(control_interval_sec=7)

    def counting(cfg):
        # desired alternates above/below total so every applied decide is
        # a scaling action; ups+downs then counts applied decides
        def init():
            return jnp.float32(0.0)

        def on_minute(state, hist, minute_idx):
            return state

        def decide(state, obs):
            desired = jnp.where(state % 2 == 0, obs.ready_total + 2.0,
                                jnp.maximum(obs.ready_total - 2.0, 1.0))
            return state + 1.0, desired, jnp.float32(0.0)

        return Controller("counting", init, on_minute, decide)

    rates = jnp.full((3,), 600.0, jnp.float32)
    out = simulate(rates, counting(cfg), cfg)
    ref = simulate_reference(rates, counting(cfg), cfg)
    np.testing.assert_array_equal(np.asarray(out.ups + out.downs),
                                  np.asarray(ref.ups + ref.downs))
    assert float((out.ups + out.downs)[1]) == pytest.approx(9.0)


def test_blocked_is_the_default_everywhere():
    """minute_step (what evals scans) IS the blocked minute; the
    reference spelling stays exported for parity work."""
    assert SC.minute_step is SC._minute_blocked
    assert SC.minute_step_reference is SC._minute_reference


def test_plant_kernel_path_matches_scan_path():
    """The fused Pallas plant kernel (interpret mode on CPU) wired into
    simulate via plant_kernel=True reproduces the scan path, vmapped and
    not."""
    cfg = SimConfig()
    rng = np.random.default_rng(5)
    ctrl = registry.get_controller("hpa", cfg)
    rates = jnp.asarray(rng.poisson(1100, 12).astype(np.float32))
    a = simulate(rates, ctrl, cfg)
    b = simulate(rates, ctrl, cfg, plant_kernel=True)
    for field in a._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(a, field)), np.asarray(getattr(b, field)),
            rtol=1e-5, atol=1e-4, err_msg=field)

    batched = jnp.asarray(rng.poisson(800, (2, 12)).astype(np.float32))
    kern = jax.jit(jax.vmap(
        lambda r: simulate(r, ctrl, cfg, plant_kernel=True)))(batched)
    scan = jax.jit(jax.vmap(lambda r: simulate(r, ctrl, cfg)))(batched)
    np.testing.assert_allclose(np.asarray(kern.served),
                               np.asarray(scan.served),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.slow
def test_bit_exact_semantics_default_interval():
    """Nightly: the eager bitwise pin again at the default 15 s interval
    over a longer trace."""
    cfg = SimConfig()
    rng = np.random.default_rng(9)
    rates = jnp.asarray(rng.poisson(1500, 4).astype(np.float32))
    with jax.disable_jit():
        for name in registry.available():
            ctrl = registry.get_controller(name, cfg)
            _assert_bit_exact(simulate(rates, ctrl, cfg),
                              simulate_reference(rates, ctrl, cfg),
                              f"eager ci=15 {name}")


@pytest.mark.slow
def test_blocked_matches_reference_long_trace():
    """Nightly: a day-long trace stays ulp-tight (no slow drift between
    the incremental pipe_sum bookkeeping of the two paths)."""
    cfg = SimConfig()
    rng = np.random.default_rng(7)
    rates = jnp.asarray(rng.poisson(2000, 1440).astype(np.float32))
    for name in registry.available():
        ctrl = registry.get_controller(name, cfg)
        _assert_ulp_tight(simulate(rates, ctrl, cfg),
                          simulate_reference(rates, ctrl, cfg),
                          f"long-trace {name}")
