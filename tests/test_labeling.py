"""Weak-supervision labeling functions + majority vote."""
import numpy as np
import jax.numpy as jnp

from repro.core import features as F
from repro.core import labeling as L
from repro.core.archetypes import Archetype


def _feats(w):
    return F.extract_features(jnp.asarray(np.asarray(w, np.float32)))


def test_spike_window_labels_spike():
    w = np.full((1, 60), 5.0)
    w[0, 30:33] = [500.0, 300.0, 150.0]
    labels, conf, n = L.weak_label(_feats(w))
    assert int(labels[0]) == Archetype.SPIKE
    assert float(conf[0]) > 0.5


def test_periodic_window_labels_periodic():
    t = np.arange(60)
    w = (100 + 80 * np.sin(2 * np.pi * t / 12.0))[None]
    labels, conf, n = L.weak_label(_feats(w))
    assert int(labels[0]) == Archetype.PERIODIC


def test_ramp_window_labels_ramp():
    t = np.arange(60, dtype=np.float64)
    w = (50 + 40 * t)[None]
    labels, conf, n = L.weak_label(_feats(w))
    assert int(labels[0]) == Archetype.RAMP


def test_stationary_window_labels_stationary():
    rng = np.random.default_rng(3)
    w = rng.normal(1000, 30, (1, 60))
    labels, conf, n = L.weak_label(_feats(w))
    assert int(labels[0]) == Archetype.STATIONARY_NOISY


def test_vote_abstain_when_no_lf_fires():
    votes = jnp.full((4, L.N_LFS), L.ABSTAIN, jnp.int32)
    labels, conf, n = L.majority_vote(votes)
    assert np.all(np.asarray(labels) == L.ABSTAIN)
    assert np.all(np.asarray(conf) == 0.0)
    assert np.all(np.asarray(n) == 0)


def test_vote_confidence_is_agreement_fraction():
    votes = jnp.asarray([[1, 1, 1, 0, -1, -1, -1, -1, -1, -1]], jnp.int32)
    labels, conf, n = L.majority_vote(votes)
    assert int(labels[0]) == 1
    assert float(conf[0]) == 0.75  # 3 of 4 non-abstaining agree
    assert int(n[0]) == 4


def test_lf_outputs_in_range():
    rng = np.random.default_rng(0)
    w = rng.gamma(2.0, 20.0, size=(64, 60))
    votes = np.asarray(L.apply_lfs(_feats(w)))
    assert votes.shape == (64, L.N_LFS)
    assert set(np.unique(votes)) <= {-1, 0, 1, 2, 3}
