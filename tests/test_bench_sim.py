"""The bench_sim perf-trajectory contract: the smoke tier proves the
records and BENCH_sim.json schema (what CI uploads as an artifact); the
nightly slow tier runs the full sweep."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(tmp_path, *args):
    cmd = [sys.executable, "-m", "benchmarks.run", "sim",
           "--json", str(tmp_path), *args]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    subprocess.run(cmd, check=True, cwd=REPO, timeout=3000, env=env)
    with open(tmp_path / "BENCH_sim.json") as f:
        return json.load(f)


def _check_doc(doc, *, smoke):
    assert doc["bench"] == "sim" and doc["smoke"] is smoke
    assert not doc["failed"]
    names = [r["name"] for r in doc["records"]]
    assert names == ["sim_blocked", "sim_batch", "sim_workloads",
                     "sim_kernel", "sim_fused_decide", "sim_gbdt_kernel"]
    for r in doc["records"]:
        assert set(r) == {"name", "us_per_call", "derived"}
        assert r["us_per_call"] > 0
    blocked = doc["records"][0]
    assert blocked["derived"].startswith("aapa_blocked_speedup=")
    fused = doc["records"][4]
    assert "_interpret_fused_vs_blocked=" in fused["derived"]
    assert doc["records"][5]["derived"].startswith("lanes_per_sec=")


@pytest.mark.slow
def test_bench_sim_smoke_json_schema(tmp_path):
    """The CI smoke invocation end-to-end: stable record names, stable
    schema, machine-readable speedups."""
    _check_doc(_run(tmp_path, "--smoke"), smoke=True)


@pytest.mark.slow
def test_bench_sim_full_sweep(tmp_path):
    """Nightly: the full sweep (policy counts, workload counts,
    blocked-vs-seed, kernel-vs-ref) completes and reports sane numbers."""
    _check_doc(_run(tmp_path), smoke=False)
