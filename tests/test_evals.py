"""The repro.evals evaluation plane: device-vs-host metric parity
(including the P95/P99 histogram approximation bound), the
_weighted_quantile oracle vs np.percentile, scenario-aware REI with the
paper's constants pinned, the fused in-scan metrics simulator, the
policies x forecasters x scenarios x seeds matrix runner (ONE compile,
per-cell parity with sim.metrics.aggregate), and content-addressed
result cards (identical config -> cache hit)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypo_compat import given, settings, st

from repro.evals import artifacts, matrix
from repro.evals import metrics as EM
from repro.evals import rei as ER
from repro.scaling import batch, registry
from repro.sim import metrics as M
from repro.sim.cluster import MinuteOut, SimConfig, make_simulator

CFG = SimConfig()

# quantiles: half-log-bin representative error + slack for weighted-CDF
# tie-breaks landing on a neighboring data value
Q_RTOL = 2.5 * EM.quantile_rel_bound()


def _random_minute_out(rng, shape):
    """Random but *consistent* MinuteOut arrays (resp_sum really is a
    served-weighted response sum, violated <= served, ...)."""
    served = rng.gamma(1.5, 200.0, shape).astype(np.float32)
    served[rng.random(shape) < 0.15] = 0.0
    resp = rng.gamma(2.0, 0.4, shape).astype(np.float32)   # seconds
    return MinuteOut(
        served=served,
        violated=(served * (rng.random(shape) < 0.3)).astype(np.float32),
        cold_starts=rng.poisson(0.5, shape).astype(np.float32),
        replica_seconds=rng.gamma(2.0, 300.0, shape).astype(np.float32),
        queue_end=rng.gamma(1.0, 5.0, shape).astype(np.float32),
        resp_sum=(resp * served).astype(np.float32),
        resp_max=resp,
        ups=rng.poisson(1.0, shape).astype(np.float32),
        downs=rng.poisson(1.0, shape).astype(np.float32),
        oscillations=rng.poisson(0.3, shape).astype(np.float32),
        util_mean=rng.random(shape).astype(np.float32),
        ready_mean=rng.gamma(2.0, 3.0, shape).astype(np.float32))


def _assert_metrics_close(dev, host, *, rtol=2e-4):
    for field in EM.EpisodeMetrics._fields:
        d, h = float(np.asarray(getattr(dev, field))), getattr(host, field)
        tol = Q_RTOL if field.startswith(("p95", "p99")) else rtol
        assert d == pytest.approx(h, rel=tol, abs=1e-3), (field, d, h)


# ----------------------------------------------- device-vs-host parity ----
@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_device_metrics_match_host_aggregate(seed):
    rng = np.random.default_rng(seed)
    minutes = int(rng.integers(30, 200))
    out = _random_minute_out(rng, (3, minutes))
    dev = EM.compute(out)                                  # fields [3]
    for w in range(3):
        host = M.aggregate(MinuteOut(*[np.asarray(v)[w] for v in out]))
        one = jax.tree.map(lambda a: a[w], dev)
        _assert_metrics_close(one, host)


def test_pooled_matches_workload_axis_aggregate():
    rng = np.random.default_rng(7)
    out = _random_minute_out(rng, (4, 120))
    dev = EM.pooled(out)
    host = M.aggregate(out, workload_axis=True)
    _assert_metrics_close(dev, host)


def test_compute_handles_extra_batch_axes():
    rng = np.random.default_rng(8)
    out = _random_minute_out(rng, (2, 3, 4, 60))
    dev = EM.compute(out)
    assert np.asarray(dev.p95_response_ms).shape == (2, 3, 4)
    host = M.aggregate(MinuteOut(*[np.asarray(v)[1, 2, 0] for v in out]))
    _assert_metrics_close(jax.tree.map(lambda a: a[1, 2, 0], dev), host)


def test_fused_simulator_matches_post_hoc_and_host():
    rng = np.random.default_rng(9)
    rates = rng.poisson(1500, size=(2, 90)).astype(np.float32)
    ctrl = registry.get_controller("hpa", CFG)
    out = make_simulator(ctrl, CFG)(jnp.asarray(rates))
    pool, per_w = EM.make_metrics_simulator(ctrl, CFG)(jnp.asarray(rates))
    _assert_metrics_close(pool, M.aggregate(out, workload_axis=True))
    for w, host in enumerate(M.per_workload(out)):
        _assert_metrics_close(jax.tree.map(lambda a: a[w], per_w), host)


# ------------------------------------------------- _weighted_quantile ----
@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_weighted_quantile_matches_percentile_on_dense_weights(seed):
    rng = np.random.default_rng(seed)
    v = rng.gamma(2.0, 10.0, int(rng.integers(5, 400)))
    w = np.ones_like(v)
    for q in (0.0, 0.25, 0.5, 0.95, 0.99, 1.0):
        got = M._weighted_quantile(v, w, q)
        want = float(np.percentile(v, 100 * q, method="inverted_cdf"))
        assert got == pytest.approx(want), (q, got, want)


def test_weighted_quantile_edge_cases():
    # degenerate inputs return 0, not index-clamped garbage
    assert M._weighted_quantile(np.array([]), np.array([]), 0.5) == 0.0
    v = np.array([3.0, 7.0])
    assert M._weighted_quantile(v, np.zeros(2), 0.5) == 0.0
    # q=0 must skip zero-weight values at the head of the sort order
    assert M._weighted_quantile(np.array([5.0, 10.0]),
                                np.array([0.0, 3.0]), 0.0) == 10.0
    # q=1 must not fall past the last positively weighted value
    assert M._weighted_quantile(np.array([5.0, 10.0]),
                                np.array([3.0, 0.0]), 1.0) == 5.0
    # boundaries on dense weights
    v = np.arange(10.0)
    w = np.ones(10)
    assert M._weighted_quantile(v, w, 0.0) == 0.0
    assert M._weighted_quantile(v, w, 1.0) == 9.0


def test_hist_quantile_respects_bound():
    rng = np.random.default_rng(3)
    vals = rng.gamma(2.0, 0.3, 500).astype(np.float32)
    w = rng.gamma(1.0, 100.0, 500).astype(np.float32)
    edges = EM.response_edges()
    hist = np.zeros(edges.shape[0], np.float32)
    idx = np.asarray(EM._bin_index(jnp.asarray(vals), edges))
    np.add.at(hist, idx, w)
    for q in (0.5, 0.95, 0.99):
        approx = float(EM._hist_quantile(jnp.asarray(hist),
                                         EM._representatives(edges), q))
        exact = M._weighted_quantile(vals, w, q)
        assert approx == pytest.approx(exact, rel=Q_RTOL)


# ------------------------------------------------------------------ REI ----
def test_rei_paper_constants_are_the_defaults():
    from repro.core import rei as R
    b = R.rei(violation_rate=0.1, pod_minutes=2880.0, scaling_actions=20.0)
    assert b.s_slo == pytest.approx(0.9)
    assert b.s_eff == pytest.approx(0.5)    # 2880 / 1440 -> 1/2
    assert b.s_stab == pytest.approx(0.5)   # 20 / 10 -> 1/2
    # explicit paper constants give identical numbers
    b2 = R.rei(0.1, 2880.0, 20.0,
               baseline_pod_minutes=ER.PAPER_BASELINE_POD_MINUTES,
               baseline_actions=ER.PAPER_BASELINE_ACTIONS)
    assert b2 == b


def test_rei_scenario_aware_baselines():
    bpm, bact = ER.scenario_baselines(720, 4)
    assert float(bpm) == pytest.approx(2880.0)   # 4 pods x half a day
    assert float(bact) == pytest.approx(20.0)    # 10 x 0.5 x 4
    # a 4-workload half-day using exactly one pod per workload scores
    # s_eff = 1 under the scenario-aware baseline, 0.5 under the paper's
    aware = ER.rei(0.0, 2880.0, 20.0, minutes=720, n_workloads=4)
    paper = ER.rei(0.0, 2880.0, 20.0)
    assert float(aware.s_eff) == pytest.approx(1.0)
    assert float(paper.s_eff) == pytest.approx(0.5)


def test_rei_batched_shapes_and_sensitivity():
    v = np.full((3, 2, 4), 0.05, np.float32)
    pm = np.full((3, 2, 4), 2000.0, np.float32)
    act = np.full((3, 2, 4), 15.0, np.float32)
    b = ER.rei(v, pm, act)
    assert np.asarray(b.rei).shape == (3, 2, 4)
    s = ER.sensitivity(v, pm, act)
    assert np.asarray(s.rei).shape == (6, 3, 2, 4)
    base = ER.rei(0.05, 2000.0, 15.0).rei
    assert np.max(np.abs(np.asarray(s.rei) - float(base))) < 0.1


# ------------------------------------------------------- matrix runner ----
ACCEPT_SPEC = matrix.spec(
    "t_matrix",
    policies=("hpa", "kpa", "predictive", "aapa"),
    forecasters=("holt_winters", "ewma"),
    scenarios=(("burst_storm", {}), ("idle_wake", {}),
               ("archetype_mix", {})),
    seeds=(0, 1), n_workloads=2, minutes=60)


def test_matrix_one_compile_per_cell_parity():
    """The acceptance matrix: 4 policies x 2 forecasters x 3 scenarios x
    2 seeds in ONE compiled call, every cell matching the host oracle."""
    rates = matrix.build_rates(ACCEPT_SPEC)
    assert rates.shape == (3, 2, 2, 60)
    runner = matrix.make_runner(ACCEPT_SPEC)
    pool, per_w = runner(rates)
    assert runner._cache_size() == 1              # one compile, one call
    assert np.asarray(pool.slo_violation_rate).shape == (3, 2, 2, 4)
    assert np.asarray(per_w.slo_violation_rate).shape == (3, 2, 2, 4, 2)

    cfg = ACCEPT_SPEC.sim_config()
    sim = batch.make_batch_simulator(matrix.controllers(ACCEPT_SPEC), cfg)
    for s in range(3):
        for z in range(2):
            out = sim(jnp.asarray(rates[s, z]))   # [F*P, W, M]
            for f in range(2):
                for p in range(4):
                    host = M.aggregate(
                        jax.tree.map(lambda a: a[f * 4 + p], out),
                        workload_axis=True)
                    cell = jax.tree.map(lambda a: a[s, z, f, p], pool)
                    _assert_metrics_close(cell, host)


def test_matrix_run_is_content_addressed_cache_hit(tmp_path, monkeypatch):
    run1 = matrix.run(ACCEPT_SPEC, root=tmp_path)
    assert not run1.cached
    assert (tmp_path / f"t_matrix-{run1.card['hash']}"
            / "result.npz").exists()

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-execute the matrix")

    monkeypatch.setattr(matrix, "_execute", boom)
    run2 = matrix.run(ACCEPT_SPEC, root=tmp_path)
    assert run2.cached and run2.card["hash"] == run1.card["hash"]
    np.testing.assert_allclose(run2.result.pooled.slo_violation_rate,
                               run1.result.pooled.slo_violation_rate,
                               rtol=1e-6)
    np.testing.assert_allclose(run2.result.rei.rei, run1.result.rei.rei,
                               rtol=1e-6)
    # a different classifier id is a different address
    key2 = dict(ACCEPT_SPEC.content_key(), classifier="other")
    assert artifacts.card_hash(key2) != run1.card["hash"]
    # tables render from the loaded result too
    assert "| policy |" in artifacts.policy_table(run2.result, ACCEPT_SPEC)


def test_matrix_force_rerun_refreshes_the_artifact(tmp_path):
    """force=True must replace the on-disk card, not silently keep the
    stale one via the same-address race rule."""
    import time
    sp = matrix.spec("t_force", policies=("hpa",),
                     scenarios=("idle_wake",), seeds=(0,),
                     n_workloads=2, minutes=60)
    run1 = matrix.run(sp, root=tmp_path)
    card = tmp_path / f"t_force-{run1.card['hash']}" / "card.json"
    before = card.stat().st_mtime
    time.sleep(0.05)
    run2 = matrix.run(sp, root=tmp_path, force=True)
    assert not run2.cached
    assert card.stat().st_mtime > before


def test_matrix_rei_uses_scenario_baselines(tmp_path):
    sp = matrix.spec("t_rei", policies=("hpa",), scenarios=("idle_wake",),
                     seeds=(0,), n_workloads=2, minutes=60)
    run = matrix.run(sp, root=tmp_path)
    m, r = run.result.pooled, run.result.rei
    want = ER.rei(m.slo_violation_rate, m.replica_minutes,
                  m.scaling_actions, minutes=60, n_workloads=2)
    np.testing.assert_allclose(np.asarray(r.rei), np.asarray(want.rei),
                               rtol=1e-6)


def test_evaluate_controllers_matches_matrix_path():
    rng = np.random.default_rng(5)
    rates = rng.poisson(900, size=(2, 60)).astype(np.float32)
    ctrls = [registry.get_controller(n, CFG) for n in ("hpa", "kpa")]
    pool, per_w = matrix.evaluate_controllers(ctrls, rates, CFG)
    assert np.asarray(pool.slo_violation_rate).shape == (2,)
    assert np.asarray(per_w.slo_violation_rate).shape == (2, 2)
    out = batch.batch_simulate(ctrls, jnp.asarray(rates), CFG)
    for i in range(2):
        host = M.aggregate(jax.tree.map(lambda a: a[i], out),
                           workload_axis=True)
        _assert_metrics_close(jax.tree.map(lambda a: a[i], pool), host)


def test_matrix_requires_classifier_id_for_custom_classify():
    with pytest.raises(ValueError):
        matrix.run(ACCEPT_SPEC, classify=lambda f: None)


def test_save_card_round_trip(tmp_path):
    key = {"bench": "latency", "batch": 4096}
    card = artifacts.save_card("t_card", key, {"ms": 2.3}, root=tmp_path)
    assert artifacts.is_cached("t_card", key, tmp_path)
    assert card["hash"] == artifacts.card_hash(key)
    assert (tmp_path / f"t_card-{card['hash']}" / "card.json").exists()


# ------------------------------------------------------- nightly scale ----
@pytest.mark.slow
def test_matrix_full_scale_nightly(tmp_path):
    """Every policy x every forecaster on day-long scenarios."""
    from repro.forecast import registry as forecast_registry
    sp = matrix.spec(
        "t_full", policies=tuple(registry.available()),
        forecasters=tuple(forecast_registry.available()),
        scenarios=(("archetype_mix", {}), ("burst_storm", {}),
                   ("diurnal_ramp", {})),
        seeds=(0, 1), n_workloads=8, minutes=1440)
    run = matrix.run(sp, root=tmp_path)
    S, Z, F, P = sp.shape
    assert np.asarray(run.result.pooled.slo_violation_rate).shape == \
        (S, Z, F, P)
    assert np.isfinite(np.asarray(run.result.rei.rei)).all()
    # per-archetype/per-scenario tables render
    assert "| scenario |" in run.card["tables"]["per_scenario"]
