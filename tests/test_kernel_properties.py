"""Property-based kernel/oracle parity (via tests/_hypo_compat: real
hypothesis when installed, seeded replay otherwise): interpret-mode
Pallas kernels vs their pure-jnp `core` oracles across random window
lengths, batch sizes, periods, and deliberately non-multiple-of-tile
shapes — the regimes the fixed parametrized sweeps in test_kernels.py
don't reach."""
import numpy as np
import jax.numpy as jnp
from _hypo_compat import given, settings, st

from repro.kernels import ops, ref


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=13),
       st.integers(min_value=40, max_value=96),
       st.integers(min_value=3, max_value=24),
       st.integers(min_value=3, max_value=8))
def test_holt_winters_parity_any_shape(b, t, period, tile_b):
    """Kernel == oracle for arbitrary (batch, time, period, tile) combos,
    including batches that don't divide the sublane tile."""
    rng = np.random.default_rng(b * 7919 + t * 31 + period)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=period,
                                      tile_b=tile_b, interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=period))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=37),
       st.integers(min_value=33, max_value=72),
       st.integers(min_value=5, max_value=48))
def test_window_features_parity_any_shape(n, w, tile_n):
    """Fused feature kernel == oracle for arbitrary window counts/lengths
    and tile sizes that don't divide the batch; includes all-zero and
    spike-contaminated windows."""
    rng = np.random.default_rng(n * 104729 + w)
    x = rng.gamma(2.0, 10.0, size=(n, w)).astype(np.float32)
    x[0, :] = 0.0                       # all-zero window
    x[n // 2, w // 2] = 1e5             # spike outlier
    got = np.asarray(ops.window_features(jnp.asarray(x), tile_n=tile_n,
                                         interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def _plant_state(rng, b, s):
    """Random but physical lane state: non-negative, pipe_sum consistent
    with the pipeline to within the incremental-update drift the sim
    itself produces."""
    pipeline = rng.gamma(1.0, 0.6, (b, s)).astype(np.float32)
    return dict(
        ready=rng.gamma(2.0, 2.0, b).astype(np.float32),
        pipeline=pipeline,
        queue=rng.gamma(1.0, 25.0, b).astype(np.float32),
        wait_sum=rng.gamma(1.0, 5.0, b).astype(np.float32),
        util_ema=rng.random(b).astype(np.float32),
        cooldown=rng.uniform(0.0, 20.0, b).astype(np.float32),
        pipe_sum=pipeline.sum(axis=1).astype(np.float32),
        arrivals=rng.gamma(2.0, 30.0, b).astype(np.float32))


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=19),
       st.integers(min_value=3, max_value=40),
       st.integers(min_value=1, max_value=30),
       st.integers(min_value=2, max_value=8))
def test_plant_block_parity_any_shape(b, s, n_ticks, tile_b):
    """Fused plant kernel == blocked-scan oracle for arbitrary lane
    counts (including non-multiple-of-tile), startup depths, and control
    periods."""
    rng = np.random.default_rng(b * 7919 + s * 31 + n_ticks)
    state = {k: jnp.asarray(v) for k, v in _plant_state(rng, b, s).items()}
    ks, kt = ops.plant_tick_block(*state.values(), n_ticks=n_ticks,
                                  tile_b=tile_b, interpret=True)
    rs, rt = ref.plant_block_ref(*state.values(), n_ticks=n_ticks)
    for i, (a, e) in enumerate(zip(ks, rs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"state[{i}]")
    for i, (a, e) in enumerate(zip(kt, rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"ticks[{i}]")


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=11),
       st.integers(min_value=1, max_value=14))
def test_plant_block_padding_lanes_inert(b, n_ticks):
    """Appending lanes must not perturb the original lanes: the tile pad
    region stays inert through the whole in-VMEM tick loop."""
    rng = np.random.default_rng(b * 131 + n_ticks)
    state = _plant_state(rng, b, 30)
    solo_in = {k: jnp.asarray(v[:1]) for k, v in state.items()}
    full_in = {k: jnp.asarray(v) for k, v in state.items()}
    s1, t1 = ops.plant_tick_block(*solo_in.values(), n_ticks=n_ticks,
                                  interpret=True)
    sN, tN = ops.plant_tick_block(*full_in.values(), n_ticks=n_ticks,
                                  interpret=True)
    for a, e in zip(sN, s1):
        np.testing.assert_allclose(np.asarray(a)[:1], np.asarray(e),
                                   rtol=1e-6, atol=1e-6)
    for a, e in zip(tN, t1):
        np.testing.assert_allclose(np.asarray(a)[:1], np.asarray(e),
                                   rtol=1e-6, atol=1e-6)


_GBDT_CACHE: dict = {}


def _gbdt_params(seed, rounds, depth):
    """Tiny trained GBDTs, cached per config: fitting dominates the
    example budget otherwise."""
    from repro.core import gbdt
    key = (seed, rounds, depth)
    if key not in _GBDT_CACHE:
        rng = np.random.default_rng(seed)
        X = rng.normal(size=(64, 9)).astype(np.float32)
        y = rng.integers(0, 4, 64).astype(np.int32)
        _GBDT_CACHE[key] = gbdt.fit(
            X, y, gbdt.GBDTConfig(n_rounds=rounds, depth=depth,
                                  n_bins=16))
    return _GBDT_CACHE[key]


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=41),
       st.integers(min_value=4, max_value=24),
       st.integers(min_value=2, max_value=6),
       st.integers(min_value=2, max_value=3))
def test_gbdt_tables_parity_any_shape(n, tile_n, rounds, depth):
    """Node-table kernel is BIT-exact vs the host table path for
    arbitrary row counts (including non-multiple-of-tile), tile sizes,
    and tree geometries."""
    params = _gbdt_params(rounds * 10 + depth, rounds, depth)
    rng = np.random.default_rng(n * 7919 + tile_n)
    X = jnp.asarray(rng.normal(size=(n, 9)).astype(np.float32))
    got = np.asarray(ops.gbdt_logits(params, X, tile_n=tile_n,
                                     interpret=True))
    want = np.asarray(ref.gbdt_logits_ref(params, X))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=1, max_value=7),
       st.integers(min_value=2, max_value=5),
       st.integers(min_value=2, max_value=4))
def test_episode_block_parity_any_shape(b, m, tile_b):
    """Fused-decide episode kernel == CPU blocked-scan oracle for
    arbitrary lane counts (including non-multiple-of-tile), episode
    lengths, and tile sizes. HPA only: each distinct shape recompiles
    the whole episode kernel, so the per-policy sweep lives in the
    deterministic smoke (test_kernel_smoke)."""
    from repro.scaling import registry
    from repro.sim.cluster import SimConfig
    cfg = SimConfig(control_interval_sec=30)
    ctrl = registry.get_controller("hpa", cfg)
    rng = np.random.default_rng(b * 7919 + m * 31 + tile_b)
    rates = jnp.asarray(rng.uniform(0.0, 300.0, size=(b, m)), jnp.float32)
    got = ops.episode_block(rates, ctrl, cfg, tile_b=tile_b,
                            interpret=True)
    want = ref.episode_block_ref(rates, ctrl, cfg)
    for i, (a, e) in enumerate(zip(got, want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=3e-6, atol=1e-4,
                                   err_msg=f"MinuteOut[{i}]")


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=64, max_value=200))
def test_holt_winters_padding_lanes_inert(b, t):
    """Appending batch rows must not perturb the original rows: the tile
    pad region stays inert through the sequential recurrence."""
    rng = np.random.default_rng(b * 31 + t)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    solo = np.asarray(ops.holt_winters(jnp.asarray(y[:1]), period=12,
                                       interpret=True))
    packed = np.asarray(ops.holt_winters(jnp.asarray(y), period=12,
                                         interpret=True))
    np.testing.assert_allclose(packed[:1], solo, rtol=1e-5, atol=1e-5)
