"""Property-based kernel/oracle parity (via tests/_hypo_compat: real
hypothesis when installed, seeded replay otherwise): interpret-mode
Pallas kernels vs their pure-jnp `core` oracles across random window
lengths, batch sizes, periods, and deliberately non-multiple-of-tile
shapes — the regimes the fixed parametrized sweeps in test_kernels.py
don't reach."""
import numpy as np
import jax.numpy as jnp
from _hypo_compat import given, settings, st

from repro.kernels import ops, ref


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=13),
       st.integers(min_value=40, max_value=96),
       st.integers(min_value=3, max_value=24),
       st.integers(min_value=3, max_value=8))
def test_holt_winters_parity_any_shape(b, t, period, tile_b):
    """Kernel == oracle for arbitrary (batch, time, period, tile) combos,
    including batches that don't divide the sublane tile."""
    rng = np.random.default_rng(b * 7919 + t * 31 + period)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=period,
                                      tile_b=tile_b, interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=period))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=37),
       st.integers(min_value=33, max_value=72),
       st.integers(min_value=5, max_value=48))
def test_window_features_parity_any_shape(n, w, tile_n):
    """Fused feature kernel == oracle for arbitrary window counts/lengths
    and tile sizes that don't divide the batch; includes all-zero and
    spike-contaminated windows."""
    rng = np.random.default_rng(n * 104729 + w)
    x = rng.gamma(2.0, 10.0, size=(n, w)).astype(np.float32)
    x[0, :] = 0.0                       # all-zero window
    x[n // 2, w // 2] = 1e5             # spike outlier
    got = np.asarray(ops.window_features(jnp.asarray(x), tile_n=tile_n,
                                         interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=64, max_value=200))
def test_holt_winters_padding_lanes_inert(b, t):
    """Appending batch rows must not perturb the original rows: the tile
    pad region stays inert through the sequential recurrence."""
    rng = np.random.default_rng(b * 31 + t)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    solo = np.asarray(ops.holt_winters(jnp.asarray(y[:1]), period=12,
                                       interpret=True))
    packed = np.asarray(ops.holt_winters(jnp.asarray(y), period=12,
                                         interpret=True))
    np.testing.assert_allclose(packed[:1], solo, rtol=1e-5, atol=1e-5)
