"""Property-based kernel/oracle parity (via tests/_hypo_compat: real
hypothesis when installed, seeded replay otherwise): interpret-mode
Pallas kernels vs their pure-jnp `core` oracles across random window
lengths, batch sizes, periods, and deliberately non-multiple-of-tile
shapes — the regimes the fixed parametrized sweeps in test_kernels.py
don't reach."""
import numpy as np
import jax.numpy as jnp
from _hypo_compat import given, settings, st

from repro.kernels import ops, ref


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=13),
       st.integers(min_value=40, max_value=96),
       st.integers(min_value=3, max_value=24),
       st.integers(min_value=3, max_value=8))
def test_holt_winters_parity_any_shape(b, t, period, tile_b):
    """Kernel == oracle for arbitrary (batch, time, period, tile) combos,
    including batches that don't divide the sublane tile."""
    rng = np.random.default_rng(b * 7919 + t * 31 + period)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    got = np.asarray(ops.holt_winters(jnp.asarray(y), period=period,
                                      tile_b=tile_b, interpret=True))
    want = np.asarray(ref.holt_winters_ref(jnp.asarray(y), period=period))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=37),
       st.integers(min_value=33, max_value=72),
       st.integers(min_value=5, max_value=48))
def test_window_features_parity_any_shape(n, w, tile_n):
    """Fused feature kernel == oracle for arbitrary window counts/lengths
    and tile sizes that don't divide the batch; includes all-zero and
    spike-contaminated windows."""
    rng = np.random.default_rng(n * 104729 + w)
    x = rng.gamma(2.0, 10.0, size=(n, w)).astype(np.float32)
    x[0, :] = 0.0                       # all-zero window
    x[n // 2, w // 2] = 1e5             # spike outlier
    got = np.asarray(ops.window_features(jnp.asarray(x), tile_n=tile_n,
                                         interpret=True))
    want = np.asarray(ref.window_features_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def _plant_state(rng, b, s):
    """Random but physical lane state: non-negative, pipe_sum consistent
    with the pipeline to within the incremental-update drift the sim
    itself produces."""
    pipeline = rng.gamma(1.0, 0.6, (b, s)).astype(np.float32)
    return dict(
        ready=rng.gamma(2.0, 2.0, b).astype(np.float32),
        pipeline=pipeline,
        queue=rng.gamma(1.0, 25.0, b).astype(np.float32),
        wait_sum=rng.gamma(1.0, 5.0, b).astype(np.float32),
        util_ema=rng.random(b).astype(np.float32),
        cooldown=rng.uniform(0.0, 20.0, b).astype(np.float32),
        pipe_sum=pipeline.sum(axis=1).astype(np.float32),
        arrivals=rng.gamma(2.0, 30.0, b).astype(np.float32))


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=19),
       st.integers(min_value=3, max_value=40),
       st.integers(min_value=1, max_value=30),
       st.integers(min_value=2, max_value=8))
def test_plant_block_parity_any_shape(b, s, n_ticks, tile_b):
    """Fused plant kernel == blocked-scan oracle for arbitrary lane
    counts (including non-multiple-of-tile), startup depths, and control
    periods."""
    rng = np.random.default_rng(b * 7919 + s * 31 + n_ticks)
    state = {k: jnp.asarray(v) for k, v in _plant_state(rng, b, s).items()}
    ks, kt = ops.plant_tick_block(*state.values(), n_ticks=n_ticks,
                                  tile_b=tile_b, interpret=True)
    rs, rt = ref.plant_block_ref(*state.values(), n_ticks=n_ticks)
    for i, (a, e) in enumerate(zip(ks, rs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"state[{i}]")
    for i, (a, e) in enumerate(zip(kt, rt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(e),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"ticks[{i}]")


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=11),
       st.integers(min_value=1, max_value=14))
def test_plant_block_padding_lanes_inert(b, n_ticks):
    """Appending lanes must not perturb the original lanes: the tile pad
    region stays inert through the whole in-VMEM tick loop."""
    rng = np.random.default_rng(b * 131 + n_ticks)
    state = _plant_state(rng, b, 30)
    solo_in = {k: jnp.asarray(v[:1]) for k, v in state.items()}
    full_in = {k: jnp.asarray(v) for k, v in state.items()}
    s1, t1 = ops.plant_tick_block(*solo_in.values(), n_ticks=n_ticks,
                                  interpret=True)
    sN, tN = ops.plant_tick_block(*full_in.values(), n_ticks=n_ticks,
                                  interpret=True)
    for a, e in zip(sN, s1):
        np.testing.assert_allclose(np.asarray(a)[:1], np.asarray(e),
                                   rtol=1e-6, atol=1e-6)
    for a, e in zip(tN, t1):
        np.testing.assert_allclose(np.asarray(a)[:1], np.asarray(e),
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=9),
       st.integers(min_value=64, max_value=200))
def test_holt_winters_padding_lanes_inert(b, t):
    """Appending batch rows must not perturb the original rows: the tile
    pad region stays inert through the sequential recurrence."""
    rng = np.random.default_rng(b * 31 + t)
    y = rng.gamma(2.0, 5.0, size=(b, t)).astype(np.float32)
    solo = np.asarray(ops.holt_winters(jnp.asarray(y[:1]), period=12,
                                       interpret=True))
    packed = np.asarray(ops.holt_winters(jnp.asarray(y), period=12,
                                         interpret=True))
    np.testing.assert_allclose(packed[:1], solo, rtol=1e-5, atol=1e-5)
