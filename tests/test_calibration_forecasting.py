"""Beta calibration + Holt-Winters forecasting."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import calibration as C
from repro.core import forecasting as fc


def test_beta_calibration_improves_ece():
    rng = np.random.default_rng(0)
    N, K = 4000, 4
    # overconfident synthetic classifier: true prob ~ q but reported q^0.3
    y = rng.integers(0, K, N)
    base = rng.dirichlet(np.ones(K) * 0.7, N)
    boost = np.eye(K)[y] * 2.0
    logits = np.log(base + 1e-9) + boost
    p_true = np.exp(logits) / np.exp(logits).sum(1, keepdims=True)
    p_over = p_true ** 3.0
    p_over /= p_over.sum(1, keepdims=True)
    ece_before = C.expected_calibration_error(p_over, y)
    cal = C.fit(p_over, y)
    p_cal = np.asarray(C.calibrate(cal, jnp.asarray(p_over, jnp.float32)))
    ece_after = C.expected_calibration_error(p_cal, y)
    assert ece_after < ece_before * 0.7


def test_calibrated_probs_normalized():
    rng = np.random.default_rng(1)
    p = rng.dirichlet(np.ones(4), 100)
    cal = C.fit(p, rng.integers(0, 4, 100))
    out = np.asarray(C.calibrate(cal, jnp.asarray(p, jnp.float32)))
    np.testing.assert_allclose(out.sum(1), 1.0, rtol=1e-4)
    conf = np.asarray(C.confidence(cal, jnp.asarray(p, jnp.float32)))
    assert (conf >= 0.2).all() and (conf <= 1.0).all()


def test_hw_tracks_seasonal_signal():
    t = np.arange(1440)
    y = 100 + 50 * np.sin(2 * np.pi * t / 60.0)
    preds = np.asarray(fc.hw_smooth(jnp.asarray(y, jnp.float32)[None],
                                    period=60))[0]
    # after burn-in, one-step-ahead error should be small vs the 50-unit
    # amplitude (and keep shrinking — see EXPERIMENTS.md on the diverging
    # alpha=0.35 defaults we replaced)
    err = np.abs(preds[300:] - y[300:]).mean()
    assert err < 6.0
    late = np.abs(preds[-300:] - y[-300:]).mean()
    assert late < err  # converging, not diverging


def test_hw_forecast_max_covers_peak():
    t = np.arange(720)
    y = 100 + 50 * np.sin(2 * np.pi * t / 60.0)
    state = fc.hw_init(60, y[0])
    for v in y:
        state = fc.hw_step(state, jnp.float32(v))
    fmax = float(fc.hw_forecast_max(state, 30))
    assert fmax > 130.0  # anticipates the next peak (~150)


def test_linear_trend_forecast_exact_on_line():
    hist = jnp.asarray(10.0 + 3.0 * np.arange(30), jnp.float32)
    pred = float(fc.linear_trend_forecast(hist, horizon=10))
    assert pred == pytest.approx(10.0 + 3.0 * 39, rel=1e-4)


def test_linear_trend_forecast_clips_at_zero():
    hist = jnp.asarray(100.0 - 10.0 * np.arange(30), jnp.float32)
    assert float(fc.linear_trend_forecast(hist, horizon=30)) == 0.0
