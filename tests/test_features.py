"""Feature-extraction correctness vs scipy + invariance properties."""
import numpy as np
import pytest
import scipy.stats
from _hypo_compat import given, settings, st

import jax.numpy as jnp

from repro.core import features as F


def _windows(n=16, w=60, seed=0):
    rng = np.random.default_rng(seed)
    return rng.gamma(2.0, 10.0, size=(n, w)).astype(np.float32)


def test_feature_count_and_names():
    assert F.N_FEATURES == 38
    assert len(F.FEATURE_NAMES) == 38
    x = jnp.asarray(_windows())
    out = F.extract_features(x)
    assert out.shape == (16, 38)
    assert np.all(np.isfinite(np.asarray(out)))


def test_moments_match_scipy():
    w = _windows()
    feats = np.asarray(F.stat_time_features(jnp.asarray(w)))
    idx = {n: i for i, n in enumerate(F.STAT_TIME_NAMES)}
    np.testing.assert_allclose(feats[:, idx["mean"]], w.mean(1), rtol=1e-5)
    np.testing.assert_allclose(feats[:, idx["std"]], w.std(1), rtol=1e-4)
    np.testing.assert_allclose(
        feats[:, idx["skewness"]], scipy.stats.skew(w, axis=1),
        rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        feats[:, idx["kurtosis"]], scipy.stats.kurtosis(w, axis=1),
        rtol=2e-3, atol=2e-3)


def test_quantiles_match_numpy():
    w = _windows()
    feats = np.asarray(F.stat_time_features(jnp.asarray(w)))
    idx = {n: i for i, n in enumerate(F.STAT_TIME_NAMES)}
    np.testing.assert_allclose(feats[:, idx["median"]],
                               np.quantile(w, 0.5, axis=1), rtol=1e-5)
    np.testing.assert_allclose(feats[:, idx["q25"]],
                               np.quantile(w, 0.25, axis=1), rtol=1e-4)
    np.testing.assert_allclose(feats[:, idx["q75"]],
                               np.quantile(w, 0.75, axis=1), rtol=1e-4)


def test_trend_slope_on_pure_ramp():
    t = np.arange(60, dtype=np.float32)
    w = (10.0 + 2.0 * t)[None, :]
    feats = np.asarray(F.stat_time_features(jnp.asarray(w)))
    idx = {n: i for i, n in enumerate(F.STAT_TIME_NAMES)}
    # slope normalized by mean: 2 / mean(10 + 2t)
    assert feats[0, idx["trend_slope"]] == pytest.approx(
        2.0 / w.mean(), rel=1e-3)
    assert feats[0, idx["trend_r2"]] == pytest.approx(1.0, abs=1e-3)


def test_periodic_window_has_low_spectral_entropy():
    t = np.arange(60)
    periodic = 100 + 80 * np.sin(2 * np.pi * t / 10.0)
    noise = np.random.default_rng(0).normal(100, 5, 60)
    fp = np.asarray(F.freq_features(jnp.asarray(periodic[None])))
    fn_ = np.asarray(F.freq_features(jnp.asarray(noise[None])))
    names = {n: i for i, n in enumerate(F.FREQ_NAMES)}
    assert fp[0, names["spectral_entropy"]] < 0.35
    assert fn_[0, names["spectral_entropy"]] > 0.7
    assert fp[0, names["dominant_power_ratio"]] > 0.8


@settings(max_examples=20, deadline=None)
@given(st.floats(min_value=1.5, max_value=100.0),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_scale_invariant_features(scale, seed):
    """cv, acf, entropy, trend_r2 etc. are invariant to rate scaling."""
    rng = np.random.default_rng(seed)
    w = rng.gamma(2.0, 10.0, size=(1, 60)).astype(np.float32) + 1.0
    f1 = np.asarray(F.extract_features(jnp.asarray(w)))
    f2 = np.asarray(F.extract_features(jnp.asarray(w * scale)))
    idx = {n: i for i, n in enumerate(F.FEATURE_NAMES)}
    for name in ["cv", "skewness", "kurtosis", "acf_1", "acf_max",
                 "trend_r2", "spectral_entropy", "half_ratio"]:
        assert f1[0, idx[name]] == pytest.approx(
            f2[0, idx[name]], rel=2e-2, abs=2e-2), name


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_features_always_finite(seed):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:
        w = np.zeros((1, 60), np.float32)           # all-zero window
    elif kind == 1:
        w = rng.poisson(0.05, (1, 60)).astype(np.float32)  # sparse
    else:
        w = rng.gamma(1.0, 1e5, (1, 60)).astype(np.float32)  # huge
    out = np.asarray(F.extract_features(jnp.asarray(w)))
    assert np.all(np.isfinite(out))
