"""Use hypothesis when available; otherwise a deterministic fallback that
replays a fixed number of seeded examples (the container image may not
ship hypothesis — property tests still run, just without shrinking)."""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:
    import numpy as np

    class _Strategy:
        def __init__(self, sampler):
            self.sample = sampler

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value=0, max_value=1 << 30, **_):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value,
                                             endpoint=True)))

    def given(*strategies):
        def deco(fn):
            # no functools.wraps: pytest must see a zero-arg signature,
            # not the wrapped function's strategy parameters
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*(s.sample(rng) for s in strategies))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._max_examples = min(max_examples, 10)
            return fn
        return deco
