"""The `repro.aapaset` dataset engine: chunked jitted build (bit-exact
with the legacy host-loop path, one compile per chunk shape),
content-addressed shard cache (deterministic manifests), day-split
leakage, dataset-card bounds on `aapaset_ci`, kernel/ref feature parity
on builder chunks, sharded loaders, and classifier save/load.

Tier-1 builds `aapaset_ci` (~10K windows, seconds on CPU); the paper-
scale `aapaset_300k` build + classifier train are `slow` (nightly CI).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import aapaset
from repro.aapaset import build as B
from repro.aapaset import manifest as MF
from repro.core import features as F
from repro.core import gbdt, labeling, pipeline
from repro.core.archetypes import Archetype
from repro.data.azure_synth import MINUTES_PER_DAY, generate_traces
from repro.kernels import ops


@pytest.fixture(scope="module")
def ci_artifact(tmp_path_factory):
    """aapaset_ci built once into a temp root, shared by this module."""
    root = tmp_path_factory.mktemp("aapaset")
    built, manifest = aapaset.build_or_load(aapaset.get("aapaset_ci"),
                                            root)
    return root, built, manifest


@pytest.fixture(scope="module")
def ci_loader(ci_artifact):
    root, built, manifest = ci_artifact
    return aapaset.AAPAsetLoader(built, manifest)


# ------------------------------------------------------------- builder ----
def test_builder_bit_exact_with_legacy_path():
    """The chunked jitted builder reproduces the seed-state host loop
    (separate feature/label dispatches, variable batch) byte for byte —
    chunking, padding, and the fused jit change no output bit."""
    rng = np.random.default_rng(0)
    w = rng.gamma(2.0, 20.0, size=(3000, 60)).astype(np.float32)
    w[10] = 0.0                                     # all-zero window

    feats, labels, confs = [], [], []
    for i in range(0, len(w), 1024):                # the legacy loop
        wb = jnp.asarray(w[i:i + 1024])
        fb = F.extract_features_jit(wb)
        lb, cb, _ = labeling.weak_label(fb)
        feats.append(np.asarray(fb))
        labels.append(np.asarray(lb))
        confs.append(np.asarray(cb))

    X, y, c, votes = B.featurize_windows(w, chunk=768)
    np.testing.assert_array_equal(X, np.concatenate(feats))
    np.testing.assert_array_equal(y, np.concatenate(labels))
    np.testing.assert_array_equal(c, np.concatenate(confs))
    assert votes.shape == (len(w), labeling.N_LFS)


def test_builder_one_compile_per_chunk_shape():
    """Different dataset sizes with the same chunk reuse ONE compilation
    (the tail chunk is padded to the fixed chunk shape)."""
    rng = np.random.default_rng(1)
    before = B._build_chunk._cache_size()
    for n in (700, 1500, 2100):
        w = rng.gamma(2.0, 10.0, size=(n, 60)).astype(np.float32)
        X, y, c, v = B.featurize_windows(w, chunk=512)
        assert X.shape == (n, F.N_FEATURES)
    grown = B._build_chunk._cache_size() - before
    assert grown <= 1, f"retraced per dataset size: {grown} compilations"


def test_kernel_ref_parity_on_builder_chunks(ci_artifact):
    """Pallas window-features kernel (interpret mode) vs the kernels.ref
    oracle on real builder-produced chunks, including the zero-padded
    tail the builder feeds the jitted step."""
    _, built, _ = ci_artifact
    chunk = built.windows[:257]
    padded = np.concatenate(
        [chunk, np.zeros((255, chunk.shape[1]), np.float32)])
    got = np.asarray(ops.window_features(jnp.asarray(padded),
                                         interpret=True))
    want = np.asarray(F.stat_time_features(jnp.asarray(padded)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


# ------------------------------------------- manifest / shard cache ----
def test_same_config_same_seed_identical_manifest(tmp_path):
    """Content-addressing: two independent builds of the same config+seed
    produce identical hashes, shard digests, and dataset cards."""
    cfg = aapaset.get("aapaset_ci", n_functions=6, n_days=2)
    _, m1 = aapaset.build_or_load(cfg, tmp_path / "a")
    _, m2 = aapaset.build_or_load(cfg, tmp_path / "b")
    assert m1["hash"] == m2["hash"]
    assert [s["sha256"] for s in m1["shards"]] == \
        [s["sha256"] for s in m2["shards"]]
    assert m1["series_sha256"] == m2["series_sha256"]
    assert m1["card"] == m2["card"]


def test_execution_knobs_do_not_change_the_address():
    cfg = aapaset.get("aapaset_ci")
    assert aapaset.config_hash(cfg) == \
        aapaset.config_hash(aapaset.get("aapaset_ci", chunk=1024,
                                        shard_rows=128))
    # content fields DO change it
    assert aapaset.config_hash(cfg) != \
        aapaset.config_hash(aapaset.get("aapaset_ci", seed=1))
    # the feature implementation is a content field: kernel- and
    # ref-built artifacts must never share an address
    assert aapaset.config_hash(
        aapaset.get("aapaset_ci", feature_path="kernel")) != \
        aapaset.config_hash(aapaset.get("aapaset_ci",
                                        feature_path="ref"))
    # "auto" resolves deterministically on this backend
    import jax
    want = "kernel" if jax.default_backend() == "tpu" else "ref"
    assert cfg.resolved_feature_path() == want
    assert aapaset.config_hash(cfg) == aapaset.config_hash(
        aapaset.get("aapaset_ci", feature_path=want))


def test_cache_hit_skips_the_build(ci_artifact, monkeypatch):
    root, built, manifest = ci_artifact

    def boom(cfg):
        raise AssertionError("cache miss: build() was called")

    monkeypatch.setattr(MF, "build", boom)
    again, m = aapaset.build_or_load(aapaset.get("aapaset_ci"), root,
                                     verify=True)
    assert m["hash"] == manifest["hash"]
    np.testing.assert_array_equal(again.features, built.features)
    np.testing.assert_array_equal(again.windows, built.windows)


def test_sharding_roundtrip_multiple_shards(tmp_path):
    """Datasets larger than shard_rows split across shards and
    reassemble losslessly."""
    cfg = aapaset.get("aapaset_ci", n_functions=6, n_days=2,
                      shard_rows=500)
    built, manifest = aapaset.build_or_load(cfg, tmp_path)
    assert len(manifest["shards"]) > 1
    assert sum(s["rows"] for s in manifest["shards"]) == len(built)
    loaded = MF.load(cfg, tmp_path, verify=True)
    np.testing.assert_array_equal(loaded.features, built.features)
    np.testing.assert_array_equal(loaded.split, built.split)


# ------------------------------------------------------- day splits ----
def test_day_split_no_leakage_at_boundaries(ci_artifact):
    """Windows are assigned to splits by day-of-window-end: a window
    straddling a split boundary must land in the LATER split, so no
    test-day minute ever appears in a training window."""
    _, built, _ = ci_artifact
    day = built.day
    # a day never spans two splits
    for d in np.unique(day):
        assert len(np.unique(built.split[day == d])) == 1
    # split day ranges are disjoint and ordered train < val < test
    train_d = day[built.split == 0]
    val_d = day[built.split == 1]
    test_d = day[built.split == 2]
    assert train_d.max() < val_d.min()
    assert val_d.max() < test_d.min()
    # boundary windows: a window that starts on day d but ends on day
    # d+1 is assigned day d+1 (the later split), so its minutes never
    # leak into the earlier split
    end_min = built.start_min + built.windows.shape[1] - 1
    straddle = (built.start_min // MINUTES_PER_DAY
                < end_min // MINUTES_PER_DAY)
    assert straddle.any()
    np.testing.assert_array_equal(
        day[straddle], end_min[straddle] // MINUTES_PER_DAY + 1)


def test_day_split_respects_nondefault_window_width(tmp_path):
    """day() must use the config's window width, not the 60-min default:
    a 120-min window ending on a later day belongs to the later split."""
    cfg = aapaset.get("aapaset_ci", n_functions=6, n_days=2, window=120)
    built, _ = aapaset.build_or_load(cfg, tmp_path)
    end_min = built.start_min + 120 - 1
    np.testing.assert_array_equal(built.day,
                                  end_min // MINUTES_PER_DAY + 1)
    for d in np.unique(built.day):
        assert len(np.unique(built.split[built.day == d])) == 1


def test_default_day_split_covers_every_day_beyond_14():
    """n_days > 14 (an advertised override) must not leave later days
    unassigned — unassigned rows would silently land in train."""
    from repro.data import windows as W
    traces = generate_traces(n_functions=3, n_days=16, seed=0)
    ds = W.make_windows(traces, min_total_invocations=0.0)
    masks = W.default_day_split(ds, 16)
    total = sum(int(m.sum()) for m in masks.values())
    assert total == len(ds)
    # and at exactly 14 days it is still the paper's 1-9/10-11/12-14
    traces14 = generate_traces(n_functions=2, n_days=14, seed=1)
    ds14 = W.make_windows(traces14, min_total_invocations=0.0)
    m14 = W.default_day_split(ds14, 14)
    d = ds14.day()
    assert d[m14["train"]].max() == 9
    assert (d[m14["val"]].min(), d[m14["val"]].max()) == (10, 11)
    assert (d[m14["test"]].min(), d[m14["test"]].max()) == (12, 14)
    assert sum(int(x.sum()) for x in m14.values()) == len(ds14)


def test_ci_dataset_card_bounds(ci_artifact):
    """LF coverage/agreement bounds the paper's weak supervision relies
    on, pinned on the tier-1 artifact."""
    _, built, manifest = ci_artifact
    card = manifest["card"]
    assert card["n_windows"] > 9000            # ~10K tier-1 scale
    assert card["abstain_rate"] < 0.35
    assert card["mean_agreement"] > 0.8        # votes mostly agree
    assert card["lf_conflict_rate"] < 0.1
    assert len(card["archetypes_present"]) == 4
    cov = card["lf_coverage"]
    assert all(0.0 < c < 0.9 for c in cov.values())
    assert sum(card["split_sizes"].values()) == card["n_windows"]


# ----------------------------------------------------------- loaders ----
def test_loader_deterministic_and_disjoint_shards(ci_loader):
    a = [np.asarray(y) for _, y, _ in
         ci_loader.batches("train", 512, seed=3)]
    b = [np.asarray(y) for _, y, _ in
         ci_loader.batches("train", 512, seed=3)]
    assert all(np.array_equal(x, y) for x, y in zip(a, b))
    assert len(a) == len(b)

    # shards partition the (undropped) permutation disjointly
    full = ci_loader.split_indices("train")
    seen = []
    for s in range(3):
        for X, y, c in ci_loader.batches("train", 128, seed=0,
                                         shard_index=s, num_shards=3,
                                         drop_remainder=False):
            seen.append(np.asarray(y))
    assert sum(len(s) for s in seen) == len(full)

    # lockstep dp: with drop_remainder every shard yields the same
    # number of batches even when the split size is not divisible
    counts = [sum(1 for _ in ci_loader.batches("train", 64, seed=0,
                                               shard_index=s,
                                               num_shards=3))
              for s in range(3)]
    assert len(set(counts)) == 1 and counts[0] > 0


def test_loader_arrays_feed_gbdt_and_calibration(ci_loader):
    X, y, conf = ci_loader.arrays("train")
    assert X.shape[1] == F.N_FEATURES
    assert (y >= 0).all() and ((conf > 0) & (conf <= 1)).all()
    trained = pipeline.train_from_loader(
        ci_loader, gbdt.GBDTConfig(n_rounds=8, depth=3))
    assert trained.dataset_id == ci_loader.dataset_id
    assert trained.test_acc > 0.9


def test_loader_series_feeds_backtests(ci_loader):
    from repro.forecast import backtest
    y = ci_loader.series(max_functions=3)[:, :200]
    preds = np.asarray(backtest.batch_smooth(["ewma"], y))
    assert preds.shape == (1, 3, 200)


def test_trained_save_load_roundtrip(tmp_path, ci_loader):
    trained = pipeline.train_from_loader(
        ci_loader, gbdt.GBDTConfig(n_rounds=8, depth=3))
    trained.save(tmp_path / "clf.npz")
    loaded = pipeline.TrainedAAPA.load(tmp_path / "clf.npz")
    assert loaded.dataset_id == trained.dataset_id
    assert loaded.test_acc == trained.test_acc

    X = jnp.asarray(ci_loader.arrays("test")[0][:64])
    np.testing.assert_array_equal(
        np.asarray(gbdt.predict_logits(trained.params, X)),
        np.asarray(gbdt.predict_logits(loaded.params, X)))
    # the classify closure still jits from loaded params
    import jax
    arch, conf = jax.jit(loaded.make_classify())(X[0])
    assert arch.shape == () and 0.0 <= float(conf) <= 1.0


# ------------------------------------------- scenario trace families ----
def test_registry_names_and_scenario_families():
    assert set(aapaset.available()) >= {
        "aapaset_300k", "aapaset_ci", "spike_heavy", "regime_switch",
        "diurnal_burst"}
    spike = generate_traces(n_functions=40, n_days=2, seed=0,
                            family="spike_heavy")
    default = generate_traces(n_functions=40, n_days=2, seed=0)
    frac = (spike.pattern == Archetype.SPIKE).mean()
    assert frac > (default.pattern == Archetype.SPIKE).mean()
    assert frac > 0.4
    regime = generate_traces(n_functions=8, n_days=2, seed=0,
                             family="regime_switch")
    assert regime.counts.shape == (8, 2 * MINUTES_PER_DAY)
    assert (regime.counts >= 0).all()


def test_scenario_variant_builds_and_is_distinct(tmp_path):
    cfg = aapaset.get("diurnal_burst", n_functions=8, n_days=2)
    built, manifest = aapaset.build_or_load(cfg, tmp_path)
    assert manifest["hash"] != aapaset.config_hash(
        aapaset.get("aapaset_ci", n_functions=8, n_days=2))
    assert "SPIKE" in manifest["card"]["archetypes_present"]


# ------------------------------------------------- paper scale (slow) ----
@pytest.mark.slow
def test_aapaset_300k_build_and_train(tmp_path):
    """Nightly: the paper-scale artifact builds, its card reports all
    four archetypes at ~300K windows, and the classifier trains from the
    named artifact."""
    built, manifest = aapaset.build_or_load(aapaset.get("aapaset_300k"),
                                            tmp_path)
    card = manifest["card"]
    assert 250_000 <= card["n_windows"] <= 350_000
    assert len(card["archetypes_present"]) == 4
    assert len(manifest["shards"]) > 1     # actually sharded at scale

    loader = aapaset.AAPAsetLoader(built, manifest)
    trained = pipeline.train_from_loader(
        loader, gbdt.GBDTConfig(n_rounds=20))
    assert trained.test_acc > 0.97
    assert trained.dataset_id.startswith("aapaset_300k-")
