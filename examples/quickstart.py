"""Quickstart: the full AAPA loop in ~2 minutes on CPU.

Generates synthetic Azure-like traces, weak-labels the windows, trains the
JAX GBDT classifier with beta calibration, replays a held-out day under
HPA / Generic-Predictive / AAPA, and prints the paper's headline metrics
(SLO violations, cold starts, replica-minutes, REI).

    PYTHONPATH=src python examples/quickstart.py
"""
import hashlib

import numpy as np
import jax

from repro.core import gbdt, pipeline
from repro.data.azure_synth import generate_traces
from repro.evals import matrix
from repro.forecast import conformal, registry as forecast_registry
from repro.scaling import registry
from repro.sim.cluster import SimConfig


def _classifier_id(trained) -> str:
    """Content id for an in-memory classifier (no artifact to name): the
    digest of its fitted parameters, so retraining with different data
    or config never hits a stale result card."""
    leaves = jax.tree.leaves((trained.params, trained.cal))
    h = hashlib.sha256()
    for leaf in leaves:
        h.update(np.asarray(leaf).tobytes())
    return f"quickstart-{h.hexdigest()[:12]}"


def main():
    print("== 1. generate traces + train the archetype classifier ==")
    traces = generate_traces(n_functions=32, n_days=5, seed=11)
    trained = pipeline.train_aapa(traces,
                                  gbdt.GBDTConfig(n_rounds=20, depth=3))
    print(f"   windows={trained.n_windows}  "
          f"test_acc={trained.test_acc:.4f} (paper: 0.998)")
    print(f"   weak-label dist={np.round(trained.label_dist, 3)}")

    print("== 2. calibrate forecast uncertainty (split conformal) ==")
    fcst = forecast_registry.make("holt_winters")
    band = conformal.calibrate(fcst, traces.counts[:16, :2 * 1440],
                               alpha=0.9)
    cov = conformal.coverage(fcst, band,
                             traces.counts[:16, 2 * 1440:3 * 1440])
    print(f"   forecasters={forecast_registry.available()}")
    print(f"   holt_winters 90% band: half-width={float(band.q):.1f} "
          f"req/min  held-out coverage={cov:.3f}  "
          f"confidence={float(conformal.confidence(band)):.3f}")

    print("== 3. replay one day under every registered autoscaler ==")
    # the whole table is ONE repro.evals call: every policy simulated in
    # one compiled scan, metrics accumulated in-scan on device, REI with
    # scenario-aware baselines, and a content-addressed result card
    spec = matrix.spec("quickstart",
                       policies=tuple(registry.available()),
                       scenarios=(("archetype_mix", {}),),
                       seeds=(11,), n_workloads=16, minutes=1440)
    run = matrix.run(spec, classify=trained.make_classify(),
                     classifier_id=_classifier_id(trained))
    m, r = run.result.pooled, run.result.rei
    print(f"   {'scaler':12s} {'viol%':>7s} {'cold%':>7s} "
          f"{'rep-min':>9s} {'p95 ms':>9s} {'REI':>6s}")
    for p, name in enumerate(spec.policies):
        pick = lambda a: float(np.asarray(a)[0, 0, 0, p])  # noqa: E731
        print(f"   {name:12s} {100*pick(m.slo_violation_rate):7.3f} "
              f"{100*pick(m.cold_start_rate):7.3f} "
              f"{pick(m.replica_minutes):9.0f} "
              f"{pick(m.p95_response_ms):9.1f} {pick(r.rei):6.3f}")
    print(f"   result card: quickstart-{run.card['hash']} "
          f"(cached={run.cached}; rerunning this script is a cache hit)")

    print("== 4. wire the conformal band from step 2 into AAPA ==")
    # ad-hoc controller variants go through the same fused metrics path
    cfg = SimConfig()
    variants = {
        "aapa[native]": registry.get_controller(
            "aapa", cfg, classify=trained.make_classify()),
        "aapa[conformal]": registry.get_controller(
            "aapa", cfg, classify=trained.make_classify(), band=band),
    }
    rates = matrix.build_rates(spec)[0, 0]        # same workloads as above
    pooled, _ = matrix.evaluate_controllers(list(variants.values()),
                                            rates, cfg)
    for i, name in enumerate(variants):
        print(f"   {name:16s} viol%="
              f"{100 * float(pooled.slo_violation_rate[i]):.3f}  "
              f"rep-min={float(pooled.replica_minutes[i]):.0f}")


if __name__ == "__main__":
    main()
