"""Quickstart: the full AAPA loop in ~2 minutes on CPU.

Generates synthetic Azure-like traces, weak-labels the windows, trains the
JAX GBDT classifier with beta calibration, replays a held-out day under
HPA / Generic-Predictive / AAPA, and prints the paper's headline metrics
(SLO violations, cold starts, replica-minutes, REI).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gbdt, pipeline, rei
from repro.data.azure_synth import generate_traces
from repro.forecast import conformal, registry as forecast_registry
from repro.scaling import batch, registry
from repro.sim import metrics as M
from repro.sim.cluster import SimConfig


def main():
    print("== 1. generate traces + train the archetype classifier ==")
    traces = generate_traces(n_functions=32, n_days=5, seed=11)
    trained = pipeline.train_aapa(traces,
                                  gbdt.GBDTConfig(n_rounds=20, depth=3))
    print(f"   windows={trained.n_windows}  "
          f"test_acc={trained.test_acc:.4f} (paper: 0.998)")
    print(f"   weak-label dist={np.round(trained.label_dist, 3)}")

    print("== 2. calibrate forecast uncertainty (split conformal) ==")
    fcst = forecast_registry.make("holt_winters")
    band = conformal.calibrate(fcst, traces.counts[:16, :2 * 1440],
                               alpha=0.9)
    cov = conformal.coverage(fcst, band,
                             traces.counts[:16, 2 * 1440:3 * 1440])
    print(f"   forecasters={forecast_registry.available()}")
    print(f"   holt_winters 90% band: half-width={float(band.q):.1f} "
          f"req/min  held-out coverage={cov:.3f}  "
          f"confidence={float(conformal.confidence(band)):.3f}")

    print("== 3. replay one day under every registered autoscaler ==")
    cfg = SimConfig()
    rates = jnp.asarray(traces.counts[:16, -1440:])
    names = registry.available()
    ctrls = [registry.get_controller(n, cfg,
                                     classify=trained.make_classify(),
                                     **({"band": band}
                                        if registry.spec(n).takes_forecaster
                                        else {}))
             for n in names]
    # one jitted policies x workloads simulation for the whole table
    out_all = batch.batch_simulate(ctrls, rates, cfg)
    print(f"   {'scaler':12s} {'viol%':>7s} {'cold%':>7s} "
          f"{'rep-min':>9s} {'p95 ms':>9s} {'REI':>6s}")
    for p, name in enumerate(names):
        m = M.aggregate(jax.tree.map(lambda a: a[p], out_all),
                        workload_axis=True)
        r = rei.rei(m.slo_violation_rate, m.replica_minutes / 16,
                    m.oscillations / 16 + 1)
        print(f"   {name:12s} {100*m.slo_violation_rate:7.3f} "
              f"{100*m.cold_start_rate:7.3f} {m.replica_minutes:9.0f} "
              f"{m.p95_response_ms:9.1f} {r.rei:6.3f}")


if __name__ == "__main__":
    main()
