"""Weak-supervision deep-dive on a named AAPAset artifact:
labeling-function behaviour (straight off the dataset card), confusion
matrix, calibration quality.

    PYTHONPATH=src python examples/classify_workloads.py
"""
import numpy as np
import jax.numpy as jnp

from repro.aapaset.loader import AAPAsetLoader
from repro.core import calibration, gbdt, pipeline
from repro.core.archetypes import ARCHETYPE_NAMES


def main():
    loader = AAPAsetLoader.from_name("aapaset_ci")
    card = loader.manifest["card"]
    print(f"dataset {loader.dataset_id}: windows={card['n_windows']}  "
          f"abstain={card['abstain_rate']:.3f}  "
          f"conflict={card['lf_conflict_rate']:.3f}")

    print("\nper-LF coverage (fraction of windows fired):")
    for name, cov in card["lf_coverage"].items():
        print(f"  {name:28s} {cov:.3f}")

    trained = pipeline.train_from_loader(loader,
                                         gbdt.GBDTConfig(n_rounds=25))
    X, y, _ = loader.arrays("test")
    pred = np.asarray(gbdt.predict(trained.params, jnp.asarray(X)))
    conf_mat = np.zeros((4, 4), int)
    for t, p in zip(y, pred):
        conf_mat[t, p] += 1
    print(f"\ntest accuracy = {(pred == y).mean():.4f} (paper: 0.998)")
    print("confusion matrix (rows = true):")
    header = "".join(f"{n[:6]:>8s}" for n in ARCHETYPE_NAMES)
    print(f"  {'':18s}{header}")
    for name, row in zip(ARCHETYPE_NAMES, conf_mat):
        print(f"  {name:18s}" + "".join(f"{v:8d}" for v in row))

    probs = np.asarray(gbdt.predict_proba(trained.params,
                                          jnp.asarray(X)))
    ece_raw = calibration.expected_calibration_error(probs, y)
    cal = np.asarray(calibration.calibrate(trained.cal,
                                           jnp.asarray(probs)))
    ece_cal = calibration.expected_calibration_error(cal, y)
    print(f"\nECE raw={ece_raw:.4f} -> beta-calibrated={ece_cal:.4f}")


if __name__ == "__main__":
    main()
