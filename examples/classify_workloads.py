"""Weak-supervision deep-dive: labeling-function behaviour, confusion
matrix, calibration quality.

    PYTHONPATH=src python examples/classify_workloads.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import calibration, gbdt, pipeline
from repro.core import labeling as L
from repro.core.archetypes import ARCHETYPE_NAMES
from repro.data import windows as W
from repro.data.azure_synth import generate_traces


def main():
    traces = generate_traces(n_functions=40, n_days=5, seed=3)
    ds = W.make_windows(traces)
    X, y, conf = pipeline.featurize_and_label(ds)
    print(f"windows={len(ds)}  abstain={np.mean(y < 0):.3f}")

    votes = np.asarray(L.apply_lfs(jnp.asarray(X[:20000])))
    print("\nper-LF coverage (fraction of windows fired):")
    for fn, cov in zip(L.LABELING_FUNCTIONS,
                       (votes >= 0).mean(axis=0)):
        print(f"  {fn.__name__:28s} {cov:.3f}")

    trained = pipeline.train_aapa(traces, gbdt.GBDTConfig(n_rounds=25))
    split = W.day_split(ds)
    m = split["test"] & (y >= 0)
    pred = np.asarray(gbdt.predict(trained.params, jnp.asarray(X[m])))
    conf_mat = np.zeros((4, 4), int)
    for t, p in zip(y[m], pred):
        conf_mat[t, p] += 1
    print(f"\ntest accuracy = {(pred == y[m]).mean():.4f} (paper: 0.998)")
    print("confusion matrix (rows = true):")
    header = "".join(f"{n[:6]:>8s}" for n in ARCHETYPE_NAMES)
    print(f"  {'':18s}{header}")
    for name, row in zip(ARCHETYPE_NAMES, conf_mat):
        print(f"  {name:18s}" + "".join(f"{v:8d}" for v in row))

    probs = np.asarray(gbdt.predict_proba(trained.params,
                                          jnp.asarray(X[m])))
    ece_raw = calibration.expected_calibration_error(probs, y[m])
    cal = np.asarray(calibration.calibrate(trained.cal,
                                           jnp.asarray(probs)))
    ece_cal = calibration.expected_calibration_error(cal, y[m])
    print(f"\nECE raw={ece_raw:.4f} -> beta-calibrated={ece_cal:.4f}")


if __name__ == "__main__":
    main()
