"""Train a small LM end-to-end on CPU with the full training substrate:
AdamW, grad accumulation, remat, async atomic checkpointing and
resume-on-restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200 [--arch id]
    # kill it mid-run and re-run: it resumes from the latest checkpoint
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.models import model as M
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_lib
from repro.train.train_step import make_train_step


def synth_batch(rng, vocab, batch, seq):
    """Synthetic 'copy-with-offset' language: learnable quickly."""
    base = rng.integers(0, vocab - 1, (batch, seq), dtype=np.int32)
    toks = np.where(np.arange(seq) % 2 == 0, base,
                    np.roll(base, 1, axis=1) % vocab)
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="experiments/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    print(f"arch={cfg.name} (reduced) params~"
          f"{cfg.param_count()/1e6:.1f}M-config-scaled")
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"actual params: {n_params/1e6:.2f}M")

    step0 = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        state, step0 = ckpt.restore(
            args.ckpt_dir,
            jax.eval_shape(lambda: {"params": params, "opt": opt_state}))
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {step0}")

    train_step = jax.jit(make_train_step(
        cfg, opt_lib.AdamWConfig(lr=3e-3, warmup_steps=20),
        microbatches=args.microbatches))
    writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=2)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for step in range(step0, args.steps):
        batch = synth_batch(rng, cfg.vocab, args.batch, args.seq)
        params, opt_state, m = train_step(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            toks_s = args.batch * args.seq * (step - step0 + 1) \
                / (time.time() - t0)
            print(f"step {step:4d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} tok/s={toks_s:.0f}")
        if (step + 1) % args.ckpt_every == 0:
            writer.save(step + 1, {"params": params, "opt": opt_state})
    writer.close()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
