"""End-to-end serving driver (the paper's kind of system, applied to model
endpoints): a reduced StableLM serves batched requests whose arrivals
follow a bursty synthetic trace; AAPA classifies the live arrival window
and scales replica lanes; we report latency/SLO/cost vs plain reactive
scaling.

    PYTHONPATH=src python examples/serve_autoscale.py [--minutes 20]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core import gbdt, pipeline
from repro.core import features as F
from repro.core.archetypes import ARCHETYPE_NAMES, table_iii_arrays
from repro.core.uncertainty import adjust
from repro.data.azure_synth import generate_traces
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine


def run(minutes: int, policy: str, trained, params, cfg, rates,
        rng) -> dict:
    eng = ServingEngine(cfg, params, lanes_per_replica=4, max_replicas=8,
                        step_time_s=0.05, startup_s=2.0, slo_s=1.5)
    classify = trained.make_classify() if trained else None
    tab = table_iii_arrays()
    rid = 0
    history = np.zeros(60, np.float32)
    steps_per_min = int(60 / eng.step_time) // 60  # sim-minute = 1s wall

    for minute in range(minutes):
        history = np.roll(history, -1)
        history[-1] = rates[minute]
        # --- control plane ---
        rate_per_s = rates[minute] / 60.0
        need = rate_per_s * 0.4 / eng.lanes  # ~0.4 s service per request
        if policy == "aapa":
            feats = F.extract_features(jnp.asarray(history)[None])[0]
            arch, conf = classify(feats)
            a = int(arch)
            adj = adjust(conf, tab["target_cpu"][a],
                         tab["cooldown_min"][a], tab["min_replicas"][a])
            warm = float(tab["warm_pool"][a])
            desired = max(np.ceil(need / float(adj.target_cpu)),
                          float(adj.min_replicas) + warm)
            label = ARCHETYPE_NAMES[a]
        else:
            desired = max(np.ceil(need / 0.7), 1)
            label = "-"
        eng.scale_to(int(desired))

        # --- data plane: one simulated minute = 20 engine steps ---
        n_req = int(rng.poisson(rates[minute] / 60.0 * 1.0))
        for _ in range(20):
            for _ in range(max(n_req // 20, 0) + (rng.random()
                           < (n_req % 20) / 20.0)):
                eng.submit(Request(rid, eng.t, prompt_len=4,
                                   gen_len=int(rng.integers(2, 6))))
                rid += 1
            eng.step()
        if minute % 5 == 0:
            print(f"  min {minute:3d} rate={rates[minute]:7.1f}/min "
                  f"arch={label:12s} replicas={eng.ready_replicas}"
                  f"+{len(eng.starting)} queue={len(eng.queue)}")
    return eng.summary()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=20)
    args = ap.parse_args()

    print("== load model (reduced stablelm-1.6b) ==")
    cfg = smoke_config(get_config("stablelm_1_6b"))
    params = M.init(jax.random.PRNGKey(0), cfg)

    print("== train archetype classifier ==")
    traces = generate_traces(n_functions=24, n_days=4, seed=5)
    trained = pipeline.train_aapa(traces,
                                  gbdt.GBDTConfig(n_rounds=15, depth=3))
    print(f"   classifier test acc = {trained.test_acc:.4f}")

    # bursty arrival trace: quiet -> spike -> quiet
    rng = np.random.default_rng(0)
    rates = np.full(args.minutes, 60.0)
    rates[args.minutes // 3:args.minutes // 3 + 3] = 1200.0

    for policy in ("reactive", "aapa"):
        print(f"== serve {args.minutes} minutes under {policy} ==")
        s = run(args.minutes, policy, trained, params, cfg, rates,
                np.random.default_rng(1))
        print(f"   served={s['served']} viol={s['slo_violation_rate']:.3f}"
              f" p95={s['p95_ms']:.0f}ms cold={s['cold_starts']}"
              f" replica_s={s['replica_seconds']:.0f}")


if __name__ == "__main__":
    main()
