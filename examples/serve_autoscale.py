"""End-to-end serving driver (the paper's kind of system, applied to model
endpoints): a reduced StableLM serves batched requests whose arrivals
follow a bursty synthetic trace; any `repro.scaling` policy scales the
replica lanes through `repro.scaling.adapter` — the identical controller
code that runs compiled inside the cluster simulator.

    PYTHONPATH=src python examples/serve_autoscale.py [--minutes 20]
"""
import argparse

import numpy as np
import jax

from repro.configs import get_config, smoke_config
from repro.core import gbdt, pipeline
from repro.core.archetypes import ARCHETYPE_NAMES
from repro.models import model as M
from repro.scaling import adapter, registry
from repro.serve.engine import Request, ServingEngine

STEPS_PER_MIN = 20     # one simulated trace-minute = 1 s of engine time
MINUTE_S = 1.0


def run(minutes: int, policy: str, trained, params, cfg, rates,
        rng) -> dict:
    eng = ServingEngine(cfg, params, lanes_per_replica=4, max_replicas=8,
                        step_time_s=MINUTE_S / STEPS_PER_MIN,
                        startup_s=2.0, slo_s=1.5)
    sim_cfg = adapter.sim_config_for_engine(eng, minute_s=MINUTE_S)
    name = {"reactive": "hpa"}.get(policy, policy)
    classify = trained.make_classify() if trained else None
    ctrl = registry.get_controller(name, sim_cfg, classify=classify)
    auto = adapter.EngineAutoscaler(eng, ctrl, sim_cfg, minute_s=MINUTE_S)

    rid = 0
    for minute in range(minutes):
        n_req = int(rng.poisson(rates[minute] / 60.0))
        for _ in range(STEPS_PER_MIN):
            burst = (n_req // STEPS_PER_MIN
                     + (rng.random() < (n_req % STEPS_PER_MIN)
                        / STEPS_PER_MIN))
            for _ in range(int(burst)):
                eng.submit(Request(rid, eng.t, prompt_len=4,
                                   gen_len=int(rng.integers(2, 6))))
                rid += 1
            eng.step()
            auto.on_tick()
        if minute % 5 == 0:
            arch = getattr(auto.ctrl_state, "arch", None)
            label = ARCHETYPE_NAMES[int(arch)] if arch is not None else "-"
            print(f"  min {minute:3d} rate={rates[minute]:7.1f}/min "
                  f"arch={label:12s} replicas={eng.ready_replicas}"
                  f"+{len(eng.starting)} queue={len(eng.queue)}")
    print_why_scaled(auto.decision_trace())
    return eng.summary()


def print_why_scaled(trace) -> None:
    """'Why scaled' digest of the adapter's DecisionRecord log: every
    executed action with the signals that drove it."""
    n = len(trace.desired)
    moves = np.nonzero((trace.scale_up > 0.5) | (trace.scale_down > 0.5)
                       | (trace.cooldown_blocked > 0.5))[0]
    print(f"  why scaled: {len(moves)} actions over {n} decisions")
    for i in moves[:12]:
        kind = ("up" if trace.scale_up[i] > 0.5 else
                "down" if trace.scale_down[i] > 0.5 else "held(cooldown)")
        fc = (f" fc={trace.fc_point[i]:.0f}/min"
              if np.isfinite(trace.fc_point[i]) else "")
        print(f"    min {int(trace.minute[i]):3d} {kind:14s} "
              f"ready={trace.ready[i]:.0f} -> target={trace.target[i]:.0f}"
              f" rate={trace.rate_rps[i]:.1f}/s{fc}")
    if len(moves) > 12:
        print(f"    ... {len(moves) - 12} more")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--minutes", type=int, default=20)
    ap.add_argument("--policies", nargs="*",
                    default=["reactive", "aapa"],
                    help=f"any of: reactive {registry.available()}")
    args = ap.parse_args()
    known = ("reactive", *registry.available())
    bad = [p for p in args.policies if p not in known]
    if bad:
        ap.error(f"unknown policies {bad}; choose from {list(known)}")

    print("== load model (reduced stablelm-1.6b) ==")
    cfg = smoke_config(get_config("stablelm_1_6b"))
    params = M.init(jax.random.PRNGKey(0), cfg)

    print("== load archetype classifier (trains + caches on first run) ==")
    # npz-cached next to the aapaset_ci artifact: reruns skip the fit
    trained = pipeline.train_classifier(
        "aapaset_ci", gbdt.GBDTConfig(n_rounds=15, depth=3))
    print(f"   classifier on {trained.dataset_id}: "
          f"test acc = {trained.test_acc:.4f}")

    # bursty arrival trace: quiet -> spike -> quiet
    rates = np.full(args.minutes, 60.0)
    rates[args.minutes // 3:args.minutes // 3 + 3] = 1200.0

    for policy in args.policies:
        print(f"== serve {args.minutes} minutes under {policy} ==")
        s = run(args.minutes, policy, trained, params, cfg, rates,
                np.random.default_rng(1))
        print(f"   served={s['served']} viol={s['slo_violation_rate']:.3f}"
              f" p95={s['p95_ms']:.0f}ms cold={s['cold_starts']}"
              f" replica_s={s['replica_seconds']:.0f}")


if __name__ == "__main__":
    main()
